"""Bucketed plan execution: the bridge between the service scheduler and
the executors' stacked entry points.

The scheduler (:mod:`repro.serve.service`) thinks in *shape signatures*
(:meth:`~repro.core.plan.ContractionPlan.shape_signature` — its quota and
metrics unit); the executors stack on the stricter
:func:`~repro.core.executors.plan_stack_key` (same topology AND array
sizes).  :func:`execute_bucketed` sits between the two: it chops an
arbitrary mix of compiled plans into same-shape micro-batches of at most
``max_batch_size``, hands each to
:meth:`~repro.core.executors.Executor.positive_batch` (which re-groups by
stack key and vmaps what it can, loops what it can't), and reports each
micro-batch's latency to the service metrics.

:func:`execute_complete_bucketed` is the same bridge for **complete-CT
queries** (positive + Möbius negative phase): the positive sub-queries of
every complete query are enumerated up front
(:func:`~repro.core.mobius.positive_queries`), deduplicated through the
positive policy, and executed via :func:`execute_bucketed`; the negative
phase then runs through :func:`~repro.core.mobius.complete_ct_many`,
which groups same-shape butterfly stacks and transforms each group in ONE
jitted dispatch (:meth:`~repro.core.executors.Executor.mobius_batch`).
"""

from __future__ import annotations

import threading
import time
from contextlib import nullcontext
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.contract import CostStats
from ..core.ct import CtTable
from ..core.database import RelationalDB
from ..core.engine import CountingEngine
from ..core.executors import Executor, plan_input_arrays, plan_stack_key
from ..core.mobius import complete_ct_many, positive_queries
from ..core.plan import ContractionPlan, group_by_signature
from ..core.variables import CtVar, LatticePoint
from ..obs.trace import NULL_TRACER, NullTracer
from .metrics import ServiceMetrics

__all__ = ["TableMerger", "execute_bucketed", "execute_bucketed_multi",
           "execute_complete_bucketed", "plan_input_arrays",
           "plan_stack_key"]


class TableMerger:
    """Batched device-side reduction of per-shard count tables.

    Count-table merging is exact addition, so it belongs on the device:
    instead of ``n_shards - 1`` sequential eager adds per query (the old
    host-side Python loop in :class:`~repro.serve.router.RouterTicket`),
    same-shape shard tables — across MANY queries at once — are stacked
    and tree-merged in ONE jitted dispatch per ``(n_partials, shape)``
    group.  Inside the trace the reduction is
    :func:`~repro.core.distributed.merge_stacked`: a ``psum`` over a
    ``data`` mesh when one device per partial exists, a stacked
    ``jnp.sum`` on one host.  The query axis is padded to the next power
    of two (replaying query 0) so the jit cache stays keyed by a handful
    of sizes.

    One instance per router; thread-safe (concurrent floods share the
    traced reducers).

    Usage::

        merged = TableMerger().merge_tables([[tab_shard0, tab_shard1]])
    """

    def __init__(self):
        self._fns: Dict[Tuple, object] = {}
        self._lock = threading.Lock()

    def _reduce_fn(self, n_partials: int, q_pad: int,
                   shape: Tuple[int, ...]):
        key = (n_partials, q_pad, shape)
        fn = self._fns.get(key)
        if fn is None:
            from ..core.distributed import merge_stacked

            def run(*flat):
                # flat is partial-major: shard s's tables for every query
                # are flat[s*q_pad : (s+1)*q_pad]
                stacked = jnp.stack(flat).reshape(
                    (n_partials, q_pad) + shape)
                out = merge_stacked(stacked)
                # per-query slices INSIDE the jit — callers get ready
                # tables, not q eager gather dispatches
                return tuple(out[i] for i in range(q_pad))

            with self._lock:
                fn = self._fns.setdefault(key, jax.jit(run))
        return fn

    def reduce_arrays(self, arrays: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Merge one query's partial count arrays (same shape) in one
        jitted dispatch — the overlapped path's partial fold."""
        arrays = list(arrays)
        if len(arrays) == 1:
            return arrays[0]
        fn = self._reduce_fn(len(arrays), 1, tuple(arrays[0].shape))
        (out,) = fn(*arrays)
        return out

    def merge_tables(self, per_query: Sequence[Sequence[CtTable]]
                     ) -> Tuple[List[CtTable], int]:
        """Merge many queries' per-shard tables, batched by shape.

        Args:
            per_query: one list of same-``vars`` shard tables per query
                (per-shard plans are compiled against the same schema, so
                shard tables of one query always align axis-for-axis).

        Returns:
            ``(merged, dispatches)``: one merged table per query in input
            order — each holding the device array straight out of the
            batched reduction, no host copy — and the number of jitted
            merge dispatches issued.

        Usage::

            merged, n_disp = merger.merge_tables(shard_tables)
        """
        merged: List[Optional[CtTable]] = [None] * len(per_query)
        groups: Dict[Tuple, List[int]] = {}
        for i, tabs in enumerate(per_query):
            if len(tabs) == 1:
                merged[i] = tabs[0]
                continue
            groups.setdefault(
                (len(tabs), tuple(tabs[0].counts.shape)), []).append(i)
        dispatches = 0
        for (n_partials, shape), idxs in groups.items():
            q = len(idxs)
            q_pad = 1 << max(q - 1, 0).bit_length()
            fn = self._reduce_fn(n_partials, q_pad, shape)
            flat: List[jnp.ndarray] = []
            for s in range(n_partials):          # partial-major layout
                flat.extend(per_query[i][s].counts for i in idxs)
                flat.extend([per_query[idxs[0]][s].counts] * (q_pad - q))
            out = fn(*flat)
            dispatches += 1
            for j, i in enumerate(idxs):
                merged[i] = CtTable(per_query[i][0].vars, out[j])
        return merged, dispatches                          # type: ignore


def execute_bucketed(executor: Executor, db: RelationalDB,
                     plans: Sequence[ContractionPlan],
                     stats: Optional[CostStats] = None,
                     max_batch_size: Optional[int] = None,
                     metrics: Optional[ServiceMetrics] = None,
                     tracer: NullTracer = NULL_TRACER
                     ) -> List[CtTable]:
    """Evaluate ``plans`` in shape-signature micro-batches.

    Results align positionally with ``plans`` and are numerically identical
    to per-plan :meth:`~repro.core.executors.Executor.positive` execution;
    only the dispatch granularity changes.

    Args:
        executor: the backend to evaluate with.
        db: the database the plans were compiled against.
        plans: compiled :class:`~repro.core.plan.ContractionPlan` list.
        stats: optional :class:`~repro.core.contract.CostStats` for
            join/row accounting.
        max_batch_size: cap per micro-batch (``None``/0 = one batch per
            signature bucket).
        metrics: optional :class:`~repro.serve.metrics.ServiceMetrics`
            that receives one ``observe_batch`` per micro-batch.
        tracer: optional :class:`~repro.obs.trace.Tracer`; each
            micro-batch dispatch becomes a ``batch.dispatch`` span
            (nested under whatever span is open on this thread).

    Returns:
        One :class:`~repro.core.ct.CtTable` per plan, in input order.

    Usage::

        tabs = execute_bucketed(engine.executor, db, plans, engine.stats)
    """
    results: List[Optional[CtTable]] = [None] * len(plans)
    for sig, idxs in group_by_signature(plans, key="shape").items():
        step = max_batch_size if max_batch_size else len(idxs)
        for s in range(0, len(idxs), max(step, 1)):
            chunk = idxs[s:s + max(step, 1)]
            span = (tracer.span("batch.dispatch", sig=sig,
                                queries=len(chunk))
                    if tracer.enabled else None)
            t0 = time.perf_counter()
            if span is not None:
                with span:
                    tabs = executor.positive_batch(
                        db, [plans[i] for i in chunk], stats)
            else:
                tabs = executor.positive_batch(db, [plans[i] for i in chunk],
                                               stats)
            dt = time.perf_counter() - t0
            if metrics is not None:
                metrics.observe_batch(sig, len(chunk), dt)
            for i, tab in zip(chunk, tabs):
                results[i] = tab
    return results


def execute_bucketed_multi(executor: Executor,
                           dbs: Sequence[RelationalDB],
                           plans: Sequence[ContractionPlan],
                           stats_list: Optional[Sequence[
                               Optional[CostStats]]] = None,
                           max_batch_size: Optional[int] = None,
                           metrics_list: Optional[Sequence[
                               Optional[ServiceMetrics]]] = None,
                           tracer: NullTracer = NULL_TRACER
                           ) -> List[CtTable]:
    """:func:`execute_bucketed` across MANY databases — the cross-tenant
    dispatch path.  Item ``i`` is ``plans[i]`` against ``dbs[i]``; plans
    from different databases that share a shape signature land in the
    same micro-batch and (when their stack keys also match) the same
    jitted dispatch via
    :meth:`~repro.core.executors.Executor.positive_batch_multi`.

    Args:
        executor: the SHARED backend (its trace/staging caches are what
            cross-tenant batching amortises).
        dbs: one database per plan.
        plans: compiled plans, positionally paired with ``dbs``.
        stats_list: optional per-item :class:`~repro.core.contract
            .CostStats` (each tenant engine's).
        max_batch_size: cap per micro-batch (``None``/0 = one batch per
            signature bucket).
        metrics_list: optional per-item
            :class:`~repro.serve.metrics.ServiceMetrics`; each distinct
            instance in a micro-batch receives one ``observe_batch`` with
            its own query count and its wall-time share of the dispatch.
        tracer: optional tracer; each micro-batch becomes a
            ``batch.dispatch`` span carrying the tenant fan-in.

    Returns:
        One :class:`~repro.core.ct.CtTable` per item, in input order.

    Usage::

        tabs = execute_bucketed_multi(executor, dbs, plans)
    """
    results: List[Optional[CtTable]] = [None] * len(plans)
    for sig, idxs in group_by_signature(plans, key="shape").items():
        step = max_batch_size if max_batch_size else len(idxs)
        for s in range(0, len(idxs), max(step, 1)):
            chunk = idxs[s:s + max(step, 1)]
            c_dbs = [dbs[i] for i in chunk]
            c_plans = [plans[i] for i in chunk]
            c_stats = ([stats_list[i] for i in chunk]
                       if stats_list is not None else None)
            span = (tracer.span("batch.dispatch", sig=sig,
                                queries=len(chunk),
                                dbs=len({id(d) for d in c_dbs}))
                    if tracer.enabled else None)
            t0 = time.perf_counter()
            if span is not None:
                with span:
                    tabs = executor.positive_batch_multi(c_dbs, c_plans,
                                                         c_stats)
            else:
                tabs = executor.positive_batch_multi(c_dbs, c_plans, c_stats)
            dt = time.perf_counter() - t0
            if metrics_list is not None:
                shares: Dict[int, Tuple[ServiceMetrics, int]] = {}
                for i in chunk:
                    m = metrics_list[i]
                    if m is not None:
                        _, n = shares.get(id(m), (m, 0))
                        shares[id(m)] = (m, n + 1)
                for m, n in shares.values():
                    m.observe_batch(sig, n, dt * n / len(chunk))
            for i, tab in zip(chunk, tabs):
                results[i] = tab
    return results


def execute_complete_bucketed(engine: CountingEngine, policy,
                              queries: Sequence[Tuple[LatticePoint,
                                                      Sequence[CtVar]]],
                              stats: Optional[CostStats] = None,
                              max_batch_size: Optional[int] = None,
                              metrics: Optional[ServiceMetrics] = None,
                              use_butterfly: bool = True) -> List[CtTable]:
    """Evaluate complete-CT queries (positive + negative phases) batched.

    Phase 1 (positive): the positive sub-queries every query's Möbius join
    will issue are enumerated, filtered to what ``policy`` would contract
    from data (:meth:`~repro.core.engine._Policy.batchable_misses`),
    executed through :func:`execute_bucketed` in signature-bucketed
    stacked dispatches, and absorbed back into the policy's cache.  Phase
    2 (negative): :func:`~repro.core.mobius.complete_ct_many` assembles
    each query's butterfly stack from the warmed cache and transforms
    same-shape groups in one jitted dispatch each.

    Results align positionally with ``queries`` and are numerically
    identical to per-query :func:`~repro.core.mobius.complete_ct`.  Time
    accounting matches the strategy path: data access lands in
    ``time_positive``, the transform in ``time_negative`` (disjointly).

    Args:
        engine: the planner/executor/cache stack to execute against.
        policy: a positive policy from :mod:`repro.core.engine`
            (``batchable_misses``/``absorb``/``positive``/``hist``).
        queries: ``(point, keep)`` pairs; ``keep`` may contain attr and
            rind axes (edge-attr axes fall back to blockwise per query).
        stats: optional :class:`~repro.core.contract.CostStats`.
        max_batch_size: positive-phase micro-batch cap (see
            :func:`execute_bucketed`).
        metrics: optional :class:`~repro.serve.metrics.ServiceMetrics`;
            receives ``observe_batch`` per positive micro-batch and
            ``observe_mobius`` per batched transform dispatch.
        use_butterfly: evaluation order, as in
            :func:`~repro.core.mobius.complete_ct`.

    Returns:
        One complete :class:`~repro.core.ct.CtTable` per query.

    Usage::

        tabs = execute_complete_bucketed(engine, policy, queries)
    """
    queries = [(point, tuple(keep)) for point, keep in queries]
    timer = ((lambda which: stats.timer(which)) if stats is not None
             else (lambda which: nullcontext()))
    pos: List[Tuple[LatticePoint, Tuple[CtVar, ...]]] = []
    for point, keep in queries:
        pos.extend(positive_queries(point, keep, use_butterfly))
    todo = policy.batchable_misses(pos)
    tracer = getattr(engine, "tracer", NULL_TRACER)
    if todo:
        plans = [engine.plan(p, k) for p, k in todo]
        with timer("positive"):
            tabs = execute_bucketed(engine.executor, engine.db, plans,
                                    stats, max_batch_size, metrics,
                                    tracer=tracer)
        for (p, _), plan, tab in zip(todo, plans, tabs):
            policy.absorb(p, plan.keep, tab)

    # the engine's fused evaluator always exists, so every
    # butterfly-eligible query takes the fused path; blockwise queries
    # fall back to per-query complete_ct over mobius_fn
    fused_fn = engine.mobius_fused_fn()
    if metrics is not None or tracer.enabled:
        inner_fused = fused_fn
        _metrics = metrics

        def fused_fn(blocks, k, perm):
            with (tracer.span("mobius.dispatch", stacks=len(blocks), k=k)
                  if tracer.enabled else nullcontext()):
                t0 = time.perf_counter()
                out = inner_fused(blocks, k, perm)
                dt = time.perf_counter() - t0
            if _metrics is not None:
                _metrics.observe_mobius(len(blocks), dt)
            return out

    # any residual data access (unwarmed misses, eviction recomputes) times
    # itself in the policy; the disjoint timer subtracts its growth to
    # keep the Fig. 3 decomposition disjoint
    with (stats.disjoint_timer("negative") if stats is not None
          else nullcontext()):
        return complete_ct_many(queries, policy, stats,
                                use_butterfly=use_butterfly,
                                mobius_fn=engine.mobius_fn(),
                                mobius_fused_fn=fused_fn)
