"""Multi-tenant serving fleet: many logical databases behind ONE shared
counting pool.

A :class:`TenantRegistry` owns the three resources worth sharing across
tenants — the jit/staging-warm :class:`~repro.core.executors.Executor`,
the byte-budgeted :class:`~repro.core.cache.CtCache` store, and the
discovery score memo — and gives every tenant its own isolated slice of
each:

* **Cache** — each tenant counts against the one global byte budget
  through a :meth:`~repro.core.cache.CtCache.scoped` view.  A tenant may
  reserve a floor (global eviction can never push it below its
  reservation) and accept a cap (its own entries shrink first once it
  crosses it), so a flooding tenant can spend the shared slack but never
  another tenant's reserved bytes.
* **Admission** — each tenant's :class:`~repro.serve.service
  .CountingService` carries a per-tenant ``admission_max`` bound layered
  UNDER the pool-level ``max_in_flight``/pending-byte backpressure: a
  flooding tenant queues inline (policy ``"queue"``) or is shed with
  :class:`~repro.serve.service.TenantAdmissionError` (policy ``"shed"``)
  while every other tenant's queue is untouched.
* **Dispatch** — :meth:`TenantRegistry.count_many` drains every involved
  tenant's queue and stacks same-shape plans from DIFFERENT tenants into
  one jitted dispatch (:func:`~repro.serve.batching
  .execute_bucketed_multi`); results are handed back through each
  tenant's own :meth:`~repro.serve.service.CountingService
  .deliver_external`, so cache writes, metrics, and trace spans stay
  per-tenant.
* **Discovery** — per-tenant :class:`~repro.discover.service
  .DiscoveryService` instances share ONE score memo; tenant-prefixed
  version tokens (:func:`~repro.discover.providers._tenant_token`) keep
  the entries disjoint, so one tenant's writes never invalidate
  another's scores.

The default-tenant shim: a bare :class:`~repro.serve.service
.CountingService` (or a private ``CtCache``) is exactly the degenerate
single-tenant registry — nothing in the single-database API changed.

Usage::

    reg = TenantRegistry(executor="dense", cache_budget_bytes=64 << 20)
    reg.add_tenant("acme", db_a, reserved_bytes=8 << 20)
    reg.add_tenant("globex", db_b, admission_max=128,
                   admission_policy="shed")
    tabs = reg.count_many([("acme", p1, None), ("globex", p2, None)])
    print(reg.stats()["tenants"]["acme"]["cache"]["hits"])
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from contextlib import ExitStack
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from ..core.cache import DEFAULT_TENANT, CtCache
from ..core.contract import CostStats
from ..core.ct import CtTable
from ..core.database import RelationalDB, ShardedDatabase
from ..core.engine import CountingEngine
from ..core.executors import Executor, make_executor
from ..core.variables import CtVar, LatticePoint
from ..obs.trace import NULL_TRACER, NullTracer, default_tracer
from .batching import execute_bucketed_multi
from .metrics import ServiceMetrics, merge_stats_dicts
from .router import CountingRouter
from .service import CountingService, CountTicket, TenantAdmissionError

__all__ = ["Tenant", "TenantRegistry", "TenantAdmissionError"]

TenantQuery = Tuple[str, LatticePoint, Optional[Sequence[CtVar]]]


class Tenant:
    """One logical database's slice of the shared pool.

    ``service`` is set for single-database tenants (their cache is a
    scoped view of the registry's shared store and their positives ride
    the cross-tenant fused dispatch); ``router`` is set for sharded
    tenants (per-shard private caches, outside the shared store's
    accounting — their floods still batch within the tenant).
    """

    __slots__ = ("tenant_id", "db", "engine", "service", "router")

    def __init__(self, tenant_id: str, db,
                 engine: Optional[CountingEngine] = None,
                 service: Optional[CountingService] = None,
                 router: Optional[CountingRouter] = None):
        self.tenant_id = tenant_id
        self.db = db
        self.engine = engine
        self.service = service
        self.router = router

    @property
    def frontend(self) -> Union[CountingService, CountingRouter]:
        """The object clients talk to: the tenant's service or router."""
        return self.service if self.service is not None else self.router


class TenantRegistry:
    """A fleet of logical databases behind one shared counting pool.

    Args:
        executor: executor spec (``"dense"``/``"sparse"``/...) or a ready
            :class:`~repro.core.executors.Executor` instance.  ONE
            instance is shared by every tenant — that is what lets
            cross-tenant batches reuse one jit/staging cache.
        cache_budget_bytes: global byte budget of the shared CT store
            (``None`` = unbounded; per-tenant floors/caps still apply).
        max_batch_size: signature-bucket dispatch size, per tenant AND
            for the cross-tenant fused dispatch.
        max_wait_s / max_in_flight / max_pending_bytes: forwarded to
            every tenant's service (pool-level backpressure).
        dtype: count dtype for engines built here.
        tracer: request tracer shared by the whole fleet (spans carry a
            ``tenant`` attribute, so one trace log splits cleanly).
        use_butterfly: Möbius evaluation order for complete-CT queries.

    Usage::

        reg = TenantRegistry()
        reg.add_tenant("a", db_a)
        tab = reg.count("a", point)
    """

    def __init__(self, *, executor: Union[str, Executor] = "dense",
                 cache_budget_bytes: Optional[int] = None,
                 max_batch_size: int = 64,
                 max_wait_s: Optional[float] = None,
                 max_in_flight: int = 1024,
                 max_pending_bytes: Optional[int] = None,
                 dtype=jnp.float32,
                 tracer: Optional[NullTracer] = None,
                 use_butterfly: bool = True):
        self.cache = CtCache(cache_budget_bytes)
        self.executor: Executor = (executor if isinstance(executor, Executor)
                                   else make_executor(executor, dtype=dtype))
        self.tracer = tracer if tracer is not None else default_tracer()
        self.cache.tracer = self.tracer
        self.max_batch_size = max_batch_size
        self._dtype = dtype
        self._svc_kw = dict(max_batch_size=max_batch_size,
                            max_wait_s=max_wait_s,
                            max_in_flight=max_in_flight,
                            max_pending_bytes=max_pending_bytes,
                            use_butterfly=use_butterfly)
        self._lock = threading.Lock()
        self._tenants: "OrderedDict[str, Tenant]" = OrderedDict()
        # one score memo for the whole fleet: tenant-prefixed version
        # tokens keep entries disjoint (see discover.providers)
        self._score_memo: Dict[Tuple, float] = {}

    # -- fleet management ----------------------------------------------------
    def add_tenant(self, tenant_id: str, db, *,
                   reserved_bytes: int = 0,
                   cache_cap_bytes: Optional[int] = None,
                   admission_max: Optional[int] = None,
                   admission_policy: str = "queue",
                   rate_limit: Optional[Tuple[int, float]] = None,
                   **overrides) -> Tenant:
        """Register a logical database under ``tenant_id``.

        Args:
            db: a :class:`~repro.core.database.RelationalDB` (joins the
                shared cache/executor pool) or a
                :class:`~repro.core.database.ShardedDatabase` (fronted by
                its own :class:`~repro.serve.router.CountingRouter`;
                per-shard caches stay private to the tenant).
            reserved_bytes: cache floor — global eviction pressure from
                OTHER tenants can never push this tenant's resident bytes
                below it.
            cache_cap_bytes: cache ceiling — this tenant's own entries
                are evicted (its own LRU first) once it crosses it.
            admission_max: per-tenant pending-query bound (``None``
                disables the gate).
            admission_policy: ``"queue"`` (flooder drains its own queue
                inline) or ``"shed"`` (raise
                :class:`~repro.serve.service.TenantAdmissionError`).
            rate_limit: per-tenant token bucket ``(n, window_s)`` — at
                most ``n`` newly admitted queries per ``window_s``
                seconds, enforced per ``admission_policy`` (see
                :class:`~repro.serve.service.CountingService`); ``None``
                disables it.
            **overrides: per-tenant overrides of the registry's service
                keywords (``max_in_flight``, ``max_pending_bytes``, ...).

        Returns:
            The new :class:`Tenant` record.

        Raises:
            ValueError: duplicate ``tenant_id``.

        Usage::

            reg.add_tenant("acme", db, reserved_bytes=4 << 20,
                           admission_max=256, admission_policy="shed")
        """
        with self._lock:
            if tenant_id in self._tenants:
                raise ValueError(f"tenant {tenant_id!r} already registered")
        svc_kw = dict(self._svc_kw)
        svc_kw.update(overrides)
        if isinstance(db, ShardedDatabase):
            router_kw = {k: v for k, v in svc_kw.items()
                         if k != "use_butterfly"}
            router = CountingRouter(db, executor=self.executor,
                                    dtype=self._dtype, tracer=self.tracer,
                                    tenant=tenant_id, **router_kw)
            tenant = Tenant(tenant_id, db, router=router)
        else:
            handle = self.cache.scoped(tenant_id)
            self.cache.set_tenant_budget(tenant_id,
                                         reserved_bytes=reserved_bytes,
                                         cap_bytes=cache_cap_bytes)
            eng = CountingEngine(db, self.executor, CostStats(),
                                 cache=handle, dtype=self._dtype)
            handle.stats = eng.stats   # mirror cache bytes into CostStats
            svc = CountingService(eng, metrics=ServiceMetrics(),
                                  tracer=self.tracer, tenant=tenant_id,
                                  admission_max=admission_max,
                                  admission_policy=admission_policy,
                                  rate_limit=rate_limit,
                                  **svc_kw)
            tenant = Tenant(tenant_id, db, engine=eng, service=svc)
        with self._lock:
            if tenant_id in self._tenants:      # lost a registration race
                raise ValueError(f"tenant {tenant_id!r} already registered")
            self._tenants[tenant_id] = tenant
        return tenant

    def remove_tenant(self, tenant_id: str) -> None:
        """Shut the tenant's frontend down, evict its cache entries, and
        release its reservation."""
        with self._lock:
            tenant = self._tenants.pop(tenant_id)
        self._shutdown_tenant(tenant)
        if tenant.service is not None:
            self.cache.set_tenant_budget(tenant_id, reserved_bytes=0,
                                         cap_bytes=None)
            self.cache.evict_all(tenant=tenant_id)

    def tenant(self, tenant_id: str) -> Tenant:
        """Look one tenant up (raises ``KeyError`` if unregistered)."""
        with self._lock:
            try:
                return self._tenants[tenant_id]
            except KeyError:
                raise KeyError(f"unknown tenant {tenant_id!r}; registered: "
                               f"{list(self._tenants)}") from None

    def tenant_ids(self) -> List[str]:
        with self._lock:
            return list(self._tenants)

    def set_tenant_budget(self, tenant_id: str, reserved_bytes: int = 0,
                          cap_bytes: Optional[int] = None) -> None:
        """Re-budget a live tenant (floor + optional cap; a cap below
        current residency shrinks immediately)."""
        self.tenant(tenant_id)         # raise on unknown ids
        self.cache.set_tenant_budget(tenant_id, reserved_bytes=reserved_bytes,
                                     cap_bytes=cap_bytes)

    # -- per-tenant pass-throughs --------------------------------------------
    def count(self, tenant_id: str, point: LatticePoint,
              keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous single count against one tenant."""
        return self.tenant(tenant_id).frontend.count(point, keep)

    def count_complete(self, tenant_id: str, point: LatticePoint,
                       keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous single complete-CT query against one tenant."""
        return self.tenant(tenant_id).frontend.count_complete(point, keep)

    def complete_many(self, tenant_id: str, queries) -> List[CtTable]:
        """One tenant's complete-CT flood (batched within the tenant)."""
        return self.tenant(tenant_id).frontend.complete_many(queries)

    def apply_delta(self, tenant_id: str, rel: str, src, dst, attrs=None,
                    **kw):
        """Write facts into ONE tenant's database.  Only that tenant's
        cache entries and score-memo token move; every other tenant's
        warm state is untouched (that is the isolation the scoped cache
        and tenant-prefixed version tokens buy)."""
        fe = self.tenant(tenant_id).frontend
        return fe.insert_facts(rel, src, dst, attrs, **kw)

    def update_attrs(self, tenant_id: str, etype: str, rows, attrs, **kw):
        """Write entity attributes into ONE tenant's database, fenced and
        reconciled like :meth:`apply_delta` — entries of OTHER tenants
        sharing the pool are untouched (their scoped cache views carry
        different tenant tags)."""
        fe = self.tenant(tenant_id).frontend
        return fe.update_attrs(etype, rows, attrs, **kw)

    def discovery(self, tenant_id: str, **kwargs):
        """The tenant's model-discovery service, sharing the fleet-wide
        score memo (built lazily on first call per tenant)."""
        kwargs.setdefault("memo", self._score_memo)
        return self.tenant(tenant_id).frontend.discovery(**kwargs)

    # -- cross-tenant fused dispatch -----------------------------------------
    def count_many(self, queries: Sequence[TenantQuery]) -> List[CtTable]:
        """Count a mixed-tenant query list with cross-tenant batching.

        Queries from different tenants whose plans share a stack
        signature ride ONE jitted dispatch on the shared executor;
        results are routed back through each tenant's own delivery path,
        so caches, metrics, and spans stay per-tenant.

        Args:
            queries: ``(tenant_id, point, keep)`` triples.

        Returns:
            One :class:`~repro.core.ct.CtTable` per query, positionally
            aligned with ``queries``.

        Usage::

            tabs = reg.count_many([("a", p, None), ("b", p, None)])
        """
        tickets: List[CountTicket] = []
        involved: "OrderedDict[str, Tenant]" = OrderedDict()
        for tid, _, _ in queries:
            if tid not in involved:
                involved[tid] = self.tenant(tid)
        with ExitStack() as stack:
            # suspend inline drains so every tenant's whole share of the
            # flood is queued before anything executes (backpressure and
            # admission bounds stay armed)
            for t in involved.values():
                if t.service is not None:
                    stack.enter_context(t.service.defer_drains())
            for tid, point, keep in queries:
                tickets.append(involved[tid].frontend.submit(point, keep))
            self._execute_cross_tenant(
                [t.service for t in involved.values()
                 if t.service is not None])
        for t in involved.values():            # sharded tenants batch
            if t.router is not None:           # within the tenant
                t.router.flush()
        return [tk.result() for tk in tickets]

    def _execute_cross_tenant(self,
                              services: Sequence[CountingService]) -> None:
        """Drain every service and run all positives through ONE
        cross-tenant bucketed dispatch; completes fall back to each
        tenant's normal path (their Möbius phase is engine-resident)."""
        drained = [(svc, svc.drain_pending()) for svc in services]
        pos: List[Tuple[CountingService, object]] = []
        for svc, entries in drained:
            pos.extend((svc, e) for e in entries if not e.complete)
        if pos:
            tr = self.tracer
            try:
                tabs = execute_bucketed_multi(
                    self.executor,
                    [svc.engine.db for svc, _ in pos],
                    [e.plan for _, e in pos],
                    [svc.engine.stats for svc, _ in pos],
                    max_batch_size=self.max_batch_size,
                    metrics_list=[svc.metrics for svc, _ in pos],
                    tracer=tr if tr.enabled else NULL_TRACER)
            except BaseException as err:
                # settle EVERY drained entry (positives and completes):
                # they are out of their queues, so an unsettled waiter
                # would hang forever
                for svc, entries in drained:
                    for e in entries:
                        if e.result is None and e.error is None:
                            e.error = err
                    svc._settle_all(entries)
                raise
            by_svc: Dict[int, Tuple[CountingService, list]] = {}
            for (svc, e), tab in zip(pos, tabs):
                by_svc.setdefault(id(svc), (svc, []))[1].append((e, tab))
            for svc, delivered in by_svc.values():
                svc.deliver_external(delivered)
        for svc, entries in drained:
            completes = [e for e in entries if e.complete]
            if completes:
                svc.execute_drained(completes)

    # -- fleet-wide control --------------------------------------------------
    def flush_all(self) -> None:
        """Drain and execute every tenant's pending queue (per-tenant
        paths; use :meth:`count_many` for the fused dispatch)."""
        for t in self._snapshot_tenants():
            t.frontend.flush()

    def shutdown(self, drain: bool = True) -> None:
        """Shut every tenant's frontend down."""
        for t in self._snapshot_tenants():
            self._shutdown_tenant(t, drain=drain)

    @staticmethod
    def _shutdown_tenant(tenant: Tenant, drain: bool = True) -> None:
        if tenant.service is not None:
            tenant.service.shutdown(drain=drain)
        else:                          # routers front one service per shard
            for svc in tenant.router.services:
                svc.shutdown(drain=drain)

    def _snapshot_tenants(self) -> List[Tenant]:
        with self._lock:
            return list(self._tenants.values())

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Fleet health rollup.

        Returns:
            ``{"tenants": {tid: frontend.stats()}, "aggregate": deep
            numeric merge across tenants, "cache": shared store info
            (with per-tenant residency/floor/cap sub-dicts)}``.

        Usage::

            reg.stats()["tenants"]["acme"]["enqueued"]
            reg.stats()["aggregate"]["cache"]["hits"]
        """
        tenants = {t.tenant_id: t.frontend.stats()
                   for t in self._snapshot_tenants()}
        # sharded tenants already publish a service-shaped "aggregate"
        # sub-dict; plain tenants' snapshots are service-shaped directly
        parts = [snap.get("aggregate", snap) for snap in tenants.values()]
        return {"tenants": tenants,
                "aggregate": merge_stats_dicts(parts),
                "cache": self.cache.info()}
