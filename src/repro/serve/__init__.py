"""Counting query service: signature-bucketed micro-batching over the
planner/executor/cache engine (:mod:`repro.core`), with cross-database
routing when the data is horizontally partitioned.

Layering::

    clients (structure search / external threads / benchmarks)
        -> CountingRouter    (shard fan-out, count merging)   router.py
        -> CountingService   (queue, buckets, backpressure)   service.py
        -> execute_bucketed  (shape-signature micro-batches)  batching.py
        -> Executor.positive_batch (stacked/vmapped plans)    core/executors.py
        -> CtCache           (shared byte-budgeted storage)   core/cache.py

A single-database deployment talks to one :class:`CountingService`
directly; a sharded deployment (:func:`~repro.core.database
.shard_database`) puts one :class:`CountingRouter` in front of one service
per shard; a multi-tenant fleet (:class:`TenantRegistry`, tenancy.py)
puts many logical databases behind ONE shared executor + byte-budgeted
cache store, with per-tenant admission control and cross-tenant fused
dispatch.  See ``docs/serving.md`` for the full API walkthrough.
"""

from .batching import (execute_bucketed, execute_bucketed_multi,
                       execute_complete_bucketed, plan_input_arrays,
                       plan_stack_key)
from .metrics import (BucketMetrics, RouterMetrics, ServiceMetrics,
                      merge_stats_dicts)
from .router import CountingRouter, NotRoutableError, RouterTicket
from .service import (CountingService, CountTicket, ServiceShutdown,
                      TenantAdmissionError)
from .tenancy import Tenant, TenantRegistry

__all__ = [
    "CountingService", "CountTicket", "ServiceShutdown",
    "CountingRouter", "RouterTicket", "NotRoutableError",
    "Tenant", "TenantRegistry", "TenantAdmissionError",
    "ServiceMetrics", "BucketMetrics", "RouterMetrics",
    "merge_stats_dicts",
    "execute_bucketed", "execute_bucketed_multi",
    "execute_complete_bucketed",
    "plan_input_arrays", "plan_stack_key",
]
