"""Counting query service: signature-bucketed micro-batching over the
planner/executor/cache engine (:mod:`repro.core`).

Layering::

    clients (structure search / external threads / benchmarks)
        -> CountingService   (queue, buckets, backpressure)  service.py
        -> execute_bucketed  (shape-signature micro-batches) batching.py
        -> Executor.positive_batch (stacked/vmapped plans)   core/executors.py
        -> CtCache           (shared byte-budgeted storage)  core/cache.py
"""

from .batching import execute_bucketed, plan_input_arrays, plan_stack_key
from .metrics import BucketMetrics, ServiceMetrics
from .service import CountingService, CountTicket

__all__ = [
    "CountingService", "CountTicket",
    "ServiceMetrics", "BucketMetrics",
    "execute_bucketed", "plan_input_arrays", "plan_stack_key",
]
