"""Cross-database routing: one counting service per shard, merged answers.

This is the horizontal-scaling front-end over a
:class:`~repro.core.database.ShardedDatabase`: the database no longer fits
one machine (or one device mesh), so it is hash-partitioned by root entity
and each shard runs its OWN planner/executor/cache stack behind its own
:class:`~repro.serve.service.CountingService`.  The
:class:`CountingRouter` is the thin layer clients talk to instead:

* each positive-count query is routed per
  :meth:`~repro.core.database.ShardedDatabase.route` — **fan-out** (every
  shard computes its partial table; the router sums them: sufficient
  statistics are additive over data partitions, Qian & Schulte's
  parallelisation) or **single-shard** (the query touches only replicated
  tables, so any one shard has the exact answer);
* shard services keep all of their batching machinery: a flood of router
  queries becomes per-shard signature-bucketed stacked dispatches;
* the router keeps its OWN result cache and in-flight table: a repeated
  query is answered from the merged-result cache without touching any
  shard, and identical *concurrent* fan-out queries coalesce onto one
  in-flight ticket instead of re-executing and re-merging per caller;
* per-shard :class:`~repro.serve.metrics.ServiceMetrics` roll up into one
  aggregate view (:meth:`CountingRouter.stats`), with routing-level
  counters (:class:`~repro.serve.metrics.RouterMetrics`) on top.

Merging is exact, not approximate: counts are integer-valued and every
satisfied grounding is counted on exactly one shard (see
``ShardedDatabase.route`` for the routability condition; unroutable
queries raise :class:`~repro.core.database.NotRoutableError` instead of
returning a wrong sum).
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from contextlib import ExitStack
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from concurrent.futures import ThreadPoolExecutor

from ..core.cache import DEFAULT_TENANT
from ..core.contract import CostStats
from ..core.ct import CtTable
from ..core.database import NotRoutableError, ShardedDatabase
from ..core.engine import CountingEngine, DeltaReport
from ..core.executors import (fanout_stack_key, make_executor,
                              plan_stack_key)
from ..core.mobius import complete_ct_many, positive_queries
from ..core.variables import CtVar, LatticePoint
from ..obs.trace import NullTracer, SpanContext, default_tracer
from .batching import TableMerger
from .metrics import RouterMetrics, ServiceMetrics, merge_stats_dicts
from .service import CountingService, CountTicket

__all__ = ["CountingRouter", "RouterTicket", "NotRoutableError"]


class RouterTicket:
    """Handle for a routed query: one per-shard
    :class:`~repro.serve.service.CountTicket` per participating shard.
    ``result()`` blocks on the shard tickets with **overlapped waits** —
    partials from shards that have already settled are folded into a
    running device-side sum (one jitted reduction, see
    :class:`~repro.serve.batching.TableMerger`) while the slower shards
    are still executing — and hands the merged device array straight into
    the router's result cache, no host copy.

    A ticket may be shared by several callers (identical concurrent
    queries coalesce onto one in-flight ticket), so the merge runs once
    under a per-ticket lock; every caller gets the same table.  A batched
    resolver (:meth:`CountingRouter.count_many`) can also install the
    merged table directly (:meth:`_install`), in which case ``result()``
    just hands it back."""

    def __init__(self, router: "CountingRouter",
                 tickets: Sequence[CountTicket], merge: bool,
                 key: Optional[Tuple] = None,
                 result: Optional[CtTable] = None,
                 epoch: int = 0,
                 trace_ctx: Optional[SpanContext] = None):
        self._router = router
        self._tickets = list(tickets)
        self._merge = merge
        self._key = key
        self._epoch = epoch            # cache generation at submit time
        self._result: Optional[CtTable] = result
        self._resolve_lock = threading.Lock()
        self._trace_ctx = trace_ctx    # the router.submit span's context
        self._t0 = time.perf_counter()  # router-level e2e reference

    @property
    def done(self) -> bool:
        return self._result is not None or all(t.done for t in self._tickets)

    def result(self, timeout: Optional[float] = None) -> CtTable:
        """The merged count table.

        Args:
            timeout: total wait bound in seconds for THIS call (None =
                wait forever) — one deadline across the lock acquire and
                every shard ticket, not a per-shard allowance.  Best
                effort: a shard wait first flushes that shard's queue
                synchronously (see :meth:`~repro.serve.service
                .CountTicket.result`), and an in-progress flush runs to
                completion before the deadline is re-checked.

        Returns:
            The single-database-equivalent :class:`~repro.core.ct.CtTable`:
            the sum of the per-shard tables for a fan-out query, the one
            shard's table otherwise.

        Raises:
            TimeoutError: the merged table was not ready within
                ``timeout``.
            BaseException: whatever a shard's batch execution raised.
        """
        if self._result is not None:
            return self._result
        deadline = None if timeout is None else time.monotonic() + timeout

        def remaining() -> Optional[float]:
            return None if deadline is None \
                else max(deadline - time.monotonic(), 0.0)

        # coalesced callers merge ONCE; the lock acquire honours the
        # caller's deadline even while another caller is mid-merge
        if not self._resolve_lock.acquire(
                timeout=-1 if timeout is None else remaining()):
            raise TimeoutError("merged count did not resolve in time")
        try:
            if self._result is None:
                try:
                    out = self._merge_overlapped(remaining)
                except BaseException:
                    self._router._forget(self._key)   # later submits retry
                    raise
                self._router._settle(self._key, out, self._epoch)
                self._result = out
                self._observe_settled("overlapped")
        finally:
            self._resolve_lock.release()
        return self._result

    def _observe_settled(self, path: str) -> None:
        """Router-level end-to-end accounting for this query: latency
        histogram, cache-install trace event, slow-query log offer."""
        router = self._router
        dt = time.perf_counter() - self._t0
        router.metrics.observe_e2e(dt)
        tr = router.tracer
        if tr.enabled:
            tr.event("router.cache_install", parent=self._trace_ctx,
                     path=path)
        slow = tr.slow
        if slow is not None and self._key is not None:
            slow.offer("router.e2e", dt, path=path, key=self._key,
                       shards=len(self._tickets))

    def _merge_overlapped(self, remaining) -> CtTable:
        """Collect the per-shard tables, merging as tickets settle: every
        pass folds all CURRENTLY settled partials (plus the running sum)
        into one device reduction, then blocks on one still-pending shard
        — so the reduction of the fast shards' tables overlaps the slow
        shards' execution instead of serialising after the slowest."""
        pending = list(self._tickets)
        if len(pending) == 1:
            return pending[0].result(remaining())
        router = self._router
        tr = router.tracer
        shard_of = {id(t): s for s, t in enumerate(self._tickets)}
        vars_out = None
        partial = None                 # running device-side sum
        n_merged = 0
        folds = 0
        straggler = 0                  # shard whose table arrived last
        t_merge0 = time.perf_counter()
        while pending:
            ready = [t for t in pending if t.done]
            if not ready:              # nothing settled: block on one shard
                ready = [pending[0]]   # (its result() flushes that shard)
            tabs = [t.result(remaining()) for t in ready]
            pending = [t for t in pending if t not in ready]
            straggler = shard_of[id(ready[-1])]
            if vars_out is None:
                vars_out = tabs[0].vars
            arrays = ([] if partial is None else [partial]) \
                + [t.counts for t in tabs]
            partial = router._merger.reduce_arrays(arrays)
            n_merged += len(tabs)
            if len(arrays) > 1:
                folds += 1
        out = CtTable(vars_out, partial)
        dt = time.perf_counter() - t_merge0
        if self._merge and n_merged > 1:
            router.metrics.inc(merged_tables=n_merged, device_merges=folds,
                               partial_merges=max(folds - 1, 0))
            router.metrics.observe_merge(dt)
            if tr.enabled:
                tr.record("router.merge", t_merge0, t_merge0 + dt,
                          parent=self._trace_ctx, path="overlapped",
                          folds=folds, merged=n_merged,
                          straggler_shard=straggler)
        return out

    def _shard_tables(self, timeout: Optional[float] = None
                      ) -> Optional[List[CtTable]]:
        """The raw per-shard tables, for a batched resolver — ``None`` if
        this ticket already carries a merged result (cache hit or a
        concurrent caller merged first)."""
        if self._result is not None:
            return None
        return [t.result(timeout) for t in self._tickets]

    def _install(self, tab: CtTable, n_merged: int) -> None:
        """Publish a batch-merged table onto this ticket (no-op if a
        concurrent caller already merged it per-ticket)."""
        with self._resolve_lock:
            if self._result is not None:
                return
            if self._merge and n_merged > 1:
                self._router.metrics.inc(merged_tables=n_merged)
            self._router._settle(self._key, tab, self._epoch)
            self._result = tab
            self._observe_settled("batched")


class _MergedProvider:
    """:class:`~repro.core.mobius.PositiveProvider` over merged shard
    answers: positive sub-pattern tables go through the router (served
    from its merged-result cache after the warm batch), per-variable
    histograms from one shard's engine — entity tables are replicated, so
    any single shard holds the exact histogram."""

    def __init__(self, router: "CountingRouter", engine: CountingEngine):
        self._router, self._engine = router, engine

    def positive(self, point: LatticePoint, keep) -> CtTable:
        return self._router.count(point, tuple(keep))

    def hist(self, var, keep) -> CtTable:
        return self._engine.hist(var, tuple(keep))


class CountingRouter:
    """Fan-out/merge front-end over one
    :class:`~repro.serve.service.CountingService` per database shard.

    Args:
        sdb: the partitioned database (see
            :func:`~repro.core.database.shard_database`).
        executor: backend name (``"dense"`` / ``"sparse"`` /
            ``"sparse_sharded"``) — one executor INSTANCE is built per
            shard so jit/batch caches never alias across shard databases —
            or a ready :class:`~repro.core.executors.Executor` instance,
            which is then shared by every shard engine.
        max_batch_size / max_wait_s / max_in_flight / max_pending_bytes:
            per-shard service knobs, passed through to every
            :class:`~repro.serve.service.CountingService`.
        cache_budget_bytes: per-shard ct-cache budget (each shard engine
            owns an independent cache).
        cache_entries: size of the router's own merged-result cache (LRU
            by entry count; ``0`` disables router-level caching).  This
            cache exists to skip the fan-out + merge entirely on repeats.
        cache_result_bytes: byte bound on the same cache (LRU-trimmed
            when either limit is crossed), so a flood of LARGE merged
            tables cannot pin unbounded front-end memory.
        dtype: accumulation dtype for every shard engine.
        metrics: routing-level counters; defaults to a fresh
            :class:`~repro.serve.metrics.RouterMetrics`.
        tracer: request tracer shared by the router AND every shard
            service/engine/cache (see :mod:`repro.obs.trace`); defaults
            to :func:`~repro.obs.trace.default_tracer` — the free no-op
            tracer unless ``REPRO_TRACE`` enables one.

    Usage::

        router = CountingRouter(shard_database(db, 4), executor="sparse")
        tab = router.count(point)          # == single-DB answer, exactly
    """

    def __init__(self, sdb: ShardedDatabase, executor="sparse",
                 max_batch_size: int = 64,
                 max_wait_s: Optional[float] = None,
                 max_in_flight: int = 1024,
                 max_pending_bytes: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 cache_entries: int = 1024,
                 cache_result_bytes: int = 64 << 20,
                 dtype=jnp.float32,
                 rebalance_rows: Optional[int] = None,
                 metrics: Optional[RouterMetrics] = None,
                 tracer: Optional[NullTracer] = None,
                 tenant: str = DEFAULT_TENANT):
        self.sdb = sdb
        self.tenant = tenant
        self.cache_entries = cache_entries
        self.cache_result_bytes = cache_result_bytes
        self.rebalance_rows = rebalance_rows
        self.metrics = metrics if metrics is not None else RouterMetrics()
        self.tracer = tracer if tracer is not None else default_tracer()
        self._lock = threading.Lock()      # metrics + router cache state
        # one writer at a time: apply_delta and rebalance serialise here
        # (readers never take it — they work on snapshots)
        self._mutate_lock = threading.Lock()
        # multi-shard read consistency: a fan-out's per-shard sub-submits
        # happen under this gate, and apply_delta holds it while fencing +
        # draining every shard — so a merged answer is always computed
        # entirely pre- or entirely post-delta, never a mix of shard
        # states that never coexisted.  Re-entrant: complete_many holds it
        # across its whole warm batch, whose fan-outs re-enter in submit()
        self._submit_gate = threading.RLock()
        self._results: "OrderedDict[Tuple, CtTable]" = OrderedDict()
        self._results_bytes = 0
        self._epoch = 0                    # bumped by invalidate()
        self._inflight: Dict[Tuple, "RouterTicket"] = {}
        self._merger = TableMerger()   # shared jitted device reducers
        self._flush_pool: Optional[ThreadPoolExecutor] = None
        # kept to build replacement services after a rebalance
        self._executor_spec = executor
        self._dtype = dtype
        self._eng_kw = dict(cache_budget_bytes=cache_budget_bytes)
        self._svc_kw = dict(max_batch_size=max_batch_size,
                            max_wait_s=max_wait_s,
                            max_in_flight=max_in_flight,
                            max_pending_bytes=max_pending_bytes,
                            tracer=self.tracer,
                            tenant=tenant)
        self._discovery = None             # lazily built DiscoveryService
        self.engines: List[CountingEngine] = []
        self.services: List[CountingService] = []
        for shard in sdb.shards:
            eng, svc = self._build_shard_stack(shard)
            self.engines.append(eng)
            self.services.append(svc)

    def _build_shard_stack(self, shard) -> Tuple[CountingEngine,
                                                 CountingService]:
        """One planner/executor/cache stack + service for one shard DB
        (one executor INSTANCE per shard unless the caller supplied a
        ready instance to share)."""
        ex = (self._executor_spec if not isinstance(self._executor_spec, str)
              else make_executor(self._executor_spec, dtype=self._dtype))
        eng = CountingEngine(shard, ex, CostStats(), dtype=self._dtype,
                             **self._eng_kw)
        return eng, CountingService(eng, **self._svc_kw)

    def _snapshot(self) -> Tuple[ShardedDatabase, List[CountingService],
                                 List[CountingEngine], int]:
        """A coherent ``(sdb, services, engines, epoch)`` view: routing
        decisions and shard submits for ONE query must come from the same
        generation, or a mid-rebalance submit could mix old and new shard
        sets (double- or under-counting the moved rows).  ``rebalance``
        swaps all three references together under the lock."""
        with self._lock:
            return self.sdb, self.services, self.engines, self._epoch

    @property
    def n_shards(self) -> int:
        return self.sdb.n_shards

    def set_tracer(self, tracer: NullTracer) -> "CountingRouter":
        """Wire one tracer through the router and every shard stack
        (services, engines, executors, caches); shard stacks built by a
        later :meth:`rebalance` inherit it too.  Pass
        :data:`~repro.obs.trace.NULL_TRACER` to turn tracing back off.

        Usage::

            router.set_tracer(Tracer())
        """
        self.tracer = tracer
        self._svc_kw["tracer"] = tracer
        for svc in self._snapshot()[1]:
            svc.set_tracer(tracer)
        return self

    # -- client API ---------------------------------------------------------
    def submit(self, point: LatticePoint,
               keep: Optional[Sequence[CtVar]] = None) -> RouterTicket:
        """Route one positive-count query; returns immediately.

        Fan-out queries enqueue on EVERY shard service (each applies its
        own batching/backpressure); single-shard queries enqueue on the
        shard that holds the full answer.  A query whose merged result is
        already in the router cache short-circuits without touching any
        shard; an identical query already in flight returns the SAME
        ticket (the fan-out executes and merges once, not once per
        caller).

        Args:
            point: lattice point to count (>= 1 atom).
            keep: ct-table axes; defaults to all entity/edge attributes of
                the point.

        Returns:
            A :class:`RouterTicket`; call ``.result()`` for the merged
            table.

        Raises:
            NotRoutableError: no additive merge exists for this query
                under the database's partitioning (see
                :meth:`~repro.core.database.ShardedDatabase.route`).
        """
        tr = self.tracer
        if not tr.enabled:
            return self._submit_routed(point, keep, None)
        with tr.span("router.submit", atoms=point.atoms) as sp:
            return self._submit_routed(point, keep, sp)

    def _submit_routed(self, point: LatticePoint,
                       keep: Optional[Sequence[CtVar]],
                       span) -> RouterTicket:
        """:meth:`submit` body; ``span`` is the open ``router.submit``
        span (or ``None`` when tracing is off) — the routing decision and
        per-shard submits are annotated onto it and its context becomes
        the parent of every downstream span of this query."""
        sdb, services, engines, epoch = self._snapshot()
        ctx = span.context if span is not None else None
        key = (point.atoms, engines[0].plan(point, keep).keep)
        with self._lock:
            self.metrics.inc(requests=1)
            hit = self._results.get(key)
            if hit is not None:
                self._results.move_to_end(key)
                self.metrics.inc(cache_hits=1)
                if span is not None:
                    span.set(mode="cache_hit")
                return RouterTicket(self, (), merge=False, result=hit,
                                    trace_ctx=ctx)
            inflight = self._inflight.get(key)
            if inflight is not None:
                self.metrics.inc(coalesced=1)
                if span is not None:
                    span.set(mode="coalesced")
                return inflight
        try:
            mode, shard = sdb.route(point)
        except NotRoutableError:
            self.metrics.inc(not_routable=1)
            if span is not None:
                span.set(mode="not_routable")
            raise
        if span is not None:
            span.set(mode=mode, shards=(len(services) if mode == "fanout"
                                        else 1))
        if mode == "fanout":
            self.metrics.inc(fanout_requests=1)
            # the gate keeps a concurrent apply_delta from landing between
            # two shard enqueues of the SAME query (see __init__)
            with self._submit_gate:
                tickets = [svc.submit(point, keep, trace_ctx=ctx)
                           for svc in services]
            ticket = RouterTicket(self, tickets, merge=True, key=key,
                                  epoch=epoch, trace_ctx=ctx)
        else:
            self.metrics.inc(single_shard_requests=1)
            ticket = RouterTicket(
                self, [services[shard % len(services)].submit(
                    point, keep, trace_ctx=ctx)],
                merge=False, key=key, epoch=epoch, trace_ctx=ctx)
        with self._lock:
            # benign race: a concurrent identical submit may have landed
            # first — keep the first ticket; shard-level coalescing already
            # dedupes the underlying work
            ticket = self._inflight.setdefault(key, ticket)
        return ticket

    def count(self, point: LatticePoint,
              keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous convenience: :meth:`submit` + merged ``result()``."""
        return self.submit(point, keep).result()

    def count_many(self, queries: Sequence[Tuple[LatticePoint,
                                                 Optional[Sequence[CtVar]]]]
                   ) -> List[CtTable]:
        """Submit a whole query list, flush every shard, return merged
        tables in submission order — the per-shard services see the full
        flood at once, so same-signature queries stack per shard, and the
        merges are batched too: same-shape shard tables across the WHOLE
        flood are reduced in one jitted device dispatch per shape group
        (see :class:`~repro.serve.batching.TableMerger`) instead of one
        eager add chain per query.

        Usage::

            tabs = router.count_many([(p, None) for p in lattice])

        Raises:
            NotRoutableError: some query has no additive merge — raised
                BEFORE anything is enqueued, so a bad query in the list
                never strands partial work on the shard queues.
        """
        sdb, services, engines, epoch = self._snapshot()
        # validate up front, enqueue nothing on a mixed good/bad list
        routes = [sdb.route(point) for point, _ in queries]
        if len(services) > 1 and queries \
                and all(mode == "fanout" for mode, _ in routes):
            out = self._count_many_fanout(sdb, engines, epoch, queries)
            if out is not None:
                return out
        # queue-only submits + one concurrent flush: no shard executes
        # inline on this thread, so shard batches overlap (see flush())
        with ExitStack() as defers:
            for svc in services:
                defers.enter_context(svc.defer_drains())
            tickets = [self.submit(point, keep) for point, keep in queries]
            self.flush()
        return self._resolve_many(tickets)

    def _count_many_fanout(self, sdb: ShardedDatabase,
                           engines: List[CountingEngine], epoch: int,
                           queries: Sequence[Tuple[LatticePoint,
                                                   Optional[Sequence[CtVar]]]]
                           ) -> Optional[List[CtTable]]:
        """All-fan-out flood fast path: reassemble the shards' input
        arrays into the unsharded database's arrays and evaluate each
        stack group ONCE (:meth:`~repro.core.executors.Executor
        .positive_fanout_merged`) — the answers are the merged tables at
        single-database cost, so sharding overhead is the routing
        bookkeeping, not ``n_shards`` evaluations plus a merge.  The shard
        services are bypassed (their caches stay cold; the router's own
        merged-result cache absorbs repeats — it is checked first on every
        path).  Returns ``None`` when the flood cannot reassemble
        (backend without a traced evaluator, or a finalise layout the jit
        cannot fuse): the caller then takes the per-shard service path.
        """
        ex0 = engines[0].executor
        dbs = [eng.db for eng in engines]
        keys: List[Tuple] = []
        plan_of: Dict[Tuple, object] = {}
        for point, keep in queries:
            plan = engines[0].plan(point, keep)
            key = (point.atoms, plan.keep)
            keys.append(key)
            plan_of[key] = plan
        # feasibility FIRST, before any metric/cache mutation, so a
        # fallback to the service path never double-counts a request
        groups: "OrderedDict[Tuple, Tuple[list, list]]" = OrderedDict()
        try:
            for key in dict.fromkeys(keys):
                plan = plan_of[key]
                lay = ex0.stacked_layout(plan)
                if lay is None:
                    return None
                fk = (fanout_stack_key(dbs, plan, sdb.partitioned), lay)
                g = groups.get(fk)
                if g is None:
                    g = groups[fk] = ([], [])
                g[0].append(plan)
                g[1].append(key)
        except NotImplementedError:
            return None
        resolved: Dict[Tuple, CtTable] = {}
        n_hits = n_coal = n_fan = 0
        with self._lock:
            seen: set = set()
            for key in keys:
                if key in resolved or key in seen:
                    if key in resolved:
                        n_hits += 1
                    else:
                        n_coal += 1
                    continue
                hit = self._results.get(key)
                if hit is not None:
                    self._results.move_to_end(key)
                    n_hits += 1
                    resolved[key] = hit
                else:
                    seen.add(key)
                    n_fan += 1
        self.metrics.inc(requests=len(keys), cache_hits=n_hits,
                         coalesced=n_coal, fanout_requests=n_fan)
        todo = seen
        if todo:
            stats = [eng.stats for eng in engines]
            # the gate linearizes the whole evaluation against
            # apply_delta/rebalance, like a service-path flood's
            # submit+flush window
            with self._submit_gate:
                for plans, gkeys in groups.values():
                    live = [(p, k) for p, k in zip(plans, gkeys)
                            if k in todo]
                    if not live:
                        continue
                    gplans = [p for p, _ in live]
                    t0 = time.perf_counter()
                    merged = ex0.positive_fanout_merged(
                        dbs, gplans, sdb.partitioned, stats)
                    dt = time.perf_counter() - t0
                    for (_, key), tab in zip(live, merged):
                        self._settle(key, tab, epoch)
                        resolved[key] = tab
                    self.metrics.inc(device_merges=1, fused_dispatches=1,
                                     merged_tables=len(gplans) * len(dbs))
                    self.metrics.observe_merge(dt)
                    tr = self.tracer
                    if tr.enabled:
                        # retroactive per-query roots: the fast path has no
                        # per-query submit, but the trace must still show
                        # which dispatch answered each query
                        t1 = t0 + dt
                        for _, key in live:
                            self.metrics.observe_e2e(dt)
                            root = tr.record("router.submit", t0, t1,
                                             mode="fanout_fused",
                                             atoms=key[0])
                            tr.record("router.merge", t0, t1, parent=root,
                                      path="fanout_fused",
                                      merged=len(dbs), shards=len(dbs))
                    else:
                        for _ in live:
                            self.metrics.observe_e2e(dt)
                    slow = self.tracer.slow
                    if slow is not None:
                        slow.offer("router.e2e", dt, path="fanout_fused",
                                   queries=len(gplans), shards=len(dbs))
        return [resolved[key] for key in keys]

    def _resolve_many(self, tickets: Sequence["RouterTicket"]
                      ) -> List[CtTable]:
        """Resolve many tickets through the batched device merge: gather
        every DISTINCT unresolved ticket's per-shard tables (coalesced
        duplicates resolve once), merge them grouped by table shape, and
        install each merged table back onto its ticket (which settles the
        router cache and any concurrent waiters)."""
        distinct: "OrderedDict[int, RouterTicket]" = OrderedDict()
        for t in tickets:
            distinct.setdefault(id(t), t)
        todo: List[RouterTicket] = []
        shard_tabs: List[List[CtTable]] = []
        for t in distinct.values():
            tabs = t._shard_tables()
            if tabs is not None:
                todo.append(t)
                shard_tabs.append(tabs)
        if todo:
            merged, dispatches = self._merger.merge_tables(shard_tabs)
            for t, tab, tabs in zip(todo, merged, shard_tabs):
                t._install(tab, len(tabs))
            if dispatches:
                self.metrics.inc(device_merges=dispatches)
        return [t.result() for t in tickets]

    # -- scheduling ---------------------------------------------------------
    def flush(self) -> None:
        """Drain every shard service's pending queue.

        When the shard queues hold the SAME fan-out flood (the
        :meth:`count_many` / :meth:`complete_many` case), every shard's
        stacked evaluation AND the cross-shard merge run in ONE jitted
        dispatch (:meth:`~repro.core.executors.Executor
        .positive_stacked_merged`): on one host, per-shard thread
        parallelism buys nothing — the GIL serialises the Python-side
        dispatches — so fusing them is what makes sharding overhead
        sublinear.  Queues that don't align (mixed routes, direct shard
        clients, complete-CT entries) fall back to one concurrent
        ``svc.flush()`` per shard."""
        services, engines = self._snapshot()[1:3]
        if len(services) <= 1:
            for svc in services:
                svc.flush()
            return
        if len(engines) == len(services) \
                and self._flush_fused(services, engines):
            return
        # list() propagates the first shard exception, like a serial loop
        list(self._get_pool(len(services)).map(
            lambda svc: svc.flush(), services))

    def _get_pool(self, n: int) -> ThreadPoolExecutor:
        pool = self._flush_pool
        if pool is None or pool._max_workers < n:
            pool = self._flush_pool = ThreadPoolExecutor(
                max_workers=n, thread_name_prefix="router-flush")
        return pool

    def _flush_fused(self, services: List[CountingService],
                     engines: List[CountingEngine]) -> bool:
        """Drain every shard queue and try the fused cross-shard dispatch;
        returns ``True`` when the drained work was fully handled (fused,
        or executed per shard as a fallback) and ``False`` only when
        nothing was drained because fusion is structurally unavailable.
        Merged tables land directly on the in-flight router tickets —
        :meth:`_resolve_many` then finds them already resolved and skips
        its merge pass."""
        drained = [svc.drain_pending() for svc in services]
        if not any(drained):
            return True
        groups = self._fused_groups(engines, drained)
        if groups is None:
            self._execute_drained(services, drained)
            return True
        ex0 = engines[0].executor
        dbs = [eng.db for eng in engines]
        exs = [eng.executor for eng in engines]
        stats = [eng.stats for eng in engines]
        try:
            for plans, per_shard_entries, keys in groups:
                t0 = time.perf_counter()
                with ExitStack() as timers:
                    for eng in engines:
                        timers.enter_context(eng.stats.timer("positive"))
                    per_shard, merged = ex0.positive_stacked_merged(
                        dbs, exs, plans, stats)
                dt = time.perf_counter() - t0
                sig = ("pos", plans[0].shape_signature())
                for s, svc in enumerate(services):
                    svc.metrics.observe_batch(sig, len(plans), dt)
                    svc.deliver_external(
                        list(zip(per_shard_entries[s], per_shard[s])))
                for key, tab in zip(keys, merged):
                    with self._lock:
                        ticket = self._inflight.get(key)
                    if ticket is not None:
                        ticket._install(tab, len(services))
                self.metrics.inc(device_merges=1, fused_dispatches=1)
                self.metrics.observe_merge(dt)
                tr = self.tracer
                if tr.enabled:
                    tr.record("router.fused_flush", t0, t0 + dt,
                              plans=len(plans), shards=len(services))
        except BaseException as err:
            # undelivered waiters must not hang: error + settle whatever
            # deliver_external has not already settled, and clear the
            # in-flight slots so later identical submits retry
            for entries in drained:
                for e in entries:
                    if not e.event.is_set():
                        if e.error is None and e.result is None:
                            e.error = err
                        e.settle()
            with self._lock:
                for _, _, keys in groups:
                    for key in keys:
                        self._inflight.pop(key, None)
            raise
        return True

    def _fused_groups(self, engines: List[CountingEngine],
                      drained: List[list]):
        """Group aligned drained entries for the fused dispatch, or
        ``None`` when the queues cannot fuse: unequal floods, complete-CT
        entries, per-shard plans that are not the same object (one compile
        cache serves every shard, so fan-outs share plans), shard stack
        keys that diverge (edge counts straddling a pow2 bucket edge), or
        a backend without a traced evaluator.  Each group is
        ``(plans, entries_per_shard, router_keys)`` with one shared stack
        key and finalise layout."""
        n = len(drained[0])
        if any(len(d) != n for d in drained):
            return None
        ex0 = engines[0].executor
        maps = []
        for d in drained:
            mp = {}
            for e in d:
                if e.complete:
                    return None
                mp[(e.point.atoms, e.keep)] = e
            maps.append(mp)
        if any(mp.keys() != maps[0].keys() for mp in maps[1:]):
            return None
        groups: Dict[Tuple, Tuple[list, list, list]] = {}
        order = []
        try:
            for e0 in drained[0]:
                key = (e0.point.atoms, e0.keep)
                plan = e0.plan
                sk = plan_stack_key(engines[0].db, plan)
                entries_s = [e0]
                for eng, mp in zip(engines[1:], maps[1:]):
                    es = mp[key]
                    if es.plan is not plan \
                            or plan_stack_key(eng.db, es.plan) != sk:
                        return None
                    entries_s.append(es)
                lay = ex0.stacked_layout(plan)
                if lay is None:
                    return None
                g = groups.get((sk, lay))
                if g is None:
                    g = groups[(sk, lay)] = (
                        [], [[] for _ in engines], [])
                    order.append(g)
                g[0].append(plan)
                for s, es in enumerate(entries_s):
                    g[1][s].append(es)
                g[2].append(key)
        except NotImplementedError:
            return None
        return order

    def _execute_drained(self, services: List[CountingService],
                         drained: List[list]) -> None:
        """Fallback for drained-but-unfusable queues: the normal batch
        path per shard, concurrently when more than one shard has work."""
        pairs = [(svc, ents) for svc, ents in zip(services, drained)
                 if ents]
        if len(pairs) <= 1:
            for svc, ents in pairs:
                svc.execute_drained(ents)
            return
        list(self._get_pool(len(pairs)).map(
            lambda p: p[0].execute_drained(p[1]), pairs))

    def pending(self) -> int:
        """Total queries pending across all shard services."""
        return sum(svc.pending() for svc in self._snapshot()[1])

    # -- complete-CT routing -------------------------------------------------
    def count_complete(self, point: LatticePoint,
                       keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Complete ct-table (positive + Möbius negative phase) over a
        sharded database: **positive-phase fan-out + front-end
        transform**.

        The Möbius join is a signed sum of positive sub-pattern tables,
        and positive tables are additive over shards — so every positive
        sub-query the join needs is routed/merged through the ordinary
        :meth:`submit` machinery (warmed as one batch, so each shard sees
        signature-bucketed dispatches), and the inclusion–exclusion runs
        once at the front-end on the merged tables.  The result is
        exactly the single-database :func:`~repro.core.mobius
        .complete_ct`.

        Args:
            point: lattice point (>= 1 relationship atom).
            keep: ct-table axes; attr, edge-attr AND rind axes of the
                point are legal (defaults to all of them).

        Returns:
            The complete :class:`~repro.core.ct.CtTable` over ``keep``.

        Raises:
            NotRoutableError: some positive sub-query has no additive
                merge under the partitioning (raised before any shard
                work is enqueued).

        Usage::

            tab = router.count_complete(point)    # == single-DB complete_ct
        """
        return self.complete_many([(point, keep)])[0]

    def complete_many(self, queries: Sequence[Tuple[LatticePoint,
                                                    Optional[Sequence[CtVar]]]]
                      ) -> List[CtTable]:
        """Route a whole complete-CT query list: every distinct positive
        sub-query across ALL queries is warmed through the shard services
        first (one fan-out batch), then each front-end transform runs on
        merged tables — see :meth:`count_complete`.

        Usage::

            tabs = router.complete_many([(p, None) for p in lattice])
        """
        sdb, services, engines, epoch = self._snapshot()
        schema = sdb.schema
        norm: List[Tuple[LatticePoint, Tuple]] = []
        for point, keep in queries:
            if keep is None:
                keep = point.all_ct_vars(schema, include_rind=True)
            norm.append((point, tuple(keep)))
        out: List[Optional[CtTable]] = [None] * len(norm)
        todo: List[int] = []
        n_hits = 0
        with self._lock:               # complete-table result cache
            for i, (point, keep) in enumerate(norm):
                hit = self._results.get(("complete", point.atoms, keep))
                if hit is not None:
                    self._results.move_to_end(("complete", point.atoms,
                                               keep))
                    n_hits += 1
                    out[i] = hit
                else:
                    todo.append(i)
        self.metrics.inc(complete_requests=len(norm), cache_hits=n_hits)
        if not todo:
            return out                                   # type: ignore
        subs: List[Tuple[LatticePoint, Tuple]] = []
        for i in todo:                 # cache hits warm nothing
            point, keep = norm[i]
            subs.extend(positive_queries(point, keep, use_butterfly=True))
        for sp, _ in subs:             # validate BEFORE enqueueing anything
            sdb.route(sp)
        # the gate spans the warm batch AND the front-end transforms: a
        # complete-CT query is a multi-read transaction, and every
        # positive sub-table its inclusion-exclusion consumes must come
        # from one side of any concurrent delta (writers wait in
        # apply_delta until the transaction finishes)
        with self._submit_gate:
            with ExitStack() as defers:
                for svc in services:
                    defers.enter_context(svc.defer_drains())
                tickets = [self.submit(sp, sk)
                           for sp, sk in dict.fromkeys(subs)]
                self.flush()
            # batched resolve: merged positives land in the router cache
            # through one device reduction per shape group
            self._resolve_many(tickets)
            provider = _MergedProvider(self, engines[0])
            # front-end negative phase, batched: same-shape butterfly
            # stacks across ALL queries transform in one jitted dispatch
            # each (mirrors the in-service complete path)
            tabs = complete_ct_many(
                [norm[i] for i in todo], provider,
                use_butterfly=True,
                mobius_fn=engines[0].mobius_fn(),
                mobius_fused_fn=engines[0].mobius_fused_fn())
            for i, tab in zip(todo, tabs):
                point, keep = norm[i]
                self._settle(("complete", point.atoms, keep), tab, epoch)
                out[i] = tab
        return out                                       # type: ignore

    # -- mutations & rebalancing ---------------------------------------------
    def apply_delta(self, rel: str, src, dst, attrs=None, *,
                    op: str = "insert",
                    **kw) -> List[Optional[DeltaReport]]:
        """Apply one write batch to the sharded store and reconcile every
        affected shard's cache, fenced across ALL shard services.

        The edges are routed exactly like reads: partitioned
        relationships hash each edge to its owning shard (untouched
        shards keep their caches hot — their report slot is ``None``);
        replicated relationships mutate the shared table once and
        reconcile everywhere.  The router's own merged-result cache is
        epoch-invalidated.  If ``rebalance_rows`` is set, any shard whose
        partitioned row count now exceeds it is split afterwards (see
        :meth:`rebalance`).

        Args:
            rel: relationship name.
            src / dst / attrs: the edge batch (see
                :meth:`~repro.core.database.RelationalDB.insert_facts`).
            op: ``"insert"`` or ``"delete"``.
            **kw: forwarded to the engines' :meth:`~repro.core.engine
                .CountingEngine.apply_delta`.

        Returns:
            One :class:`~repro.core.engine.DeltaReport` (or ``None``) per
            shard, aligned with the shard list at application time.

        Usage::

            router.apply_delta("Rated", src, dst, {"rating": vals})
        """
        if op not in ("insert", "delete"):
            raise ValueError(f"op must be 'insert' or 'delete', got {op!r}")
        with self._mutate_lock:
            sdb, services, engines, _ = self._snapshot()
            # the submit gate + queue drain make cross-shard reads
            # linearize around the write: no fan-out is mid-enqueue, and
            # every sub-query already queued executes against the
            # PRE-delta store before anything moves — so a merged answer
            # can never mix shard states from both sides of the write
            with self._submit_gate:
                with ExitStack() as fences:
                    # global fence: replicated tables are SHARED arrays, so
                    # no shard may be mid-batch while they move underneath
                    for svc in services:
                        fences.enter_context(svc.fence())
                    for svc in services:
                        svc.flush()        # re-entrant: fence locks held
                    deltas = (sdb.insert_facts(rel, src, dst, attrs)
                              if op == "insert"
                              else sdb.delete_facts(rel, src, dst))
                    reports = [svc.apply_delta(d, **kw) if d is not None
                               else None
                               for svc, d in zip(services, deltas)]
                # epoch-invalidate while the gate still blocks readers, so
                # no submit can serve a pre-delta merged result afterwards
                self.invalidate()
            self.metrics.inc(deltas=1)
        if self.rebalance_rows is not None:
            for s in range(sdb.n_shards):
                if sdb.partitioned_rows(s) > self.rebalance_rows:
                    self.rebalance(s)
        return reports

    def insert_facts(self, rel: str, src, dst, attrs=None,
                     **kw) -> List[Optional[DeltaReport]]:
        """Convenience for :meth:`apply_delta` with ``op="insert"``."""
        return self.apply_delta(rel, src, dst, attrs, op="insert", **kw)

    def delete_facts(self, rel: str, src, dst,
                     **kw) -> List[Optional[DeltaReport]]:
        """Convenience for :meth:`apply_delta` with ``op="delete"``."""
        return self.apply_delta(rel, src, dst, op="delete", **kw)

    def update_attrs(self, etype: str, rows, attrs,
                     **kw) -> List[Optional[DeltaReport]]:
        """Apply one entity-attribute write batch to the sharded store and
        reconcile every shard's cache, fenced across ALL shard services —
        the attribute analogue of :meth:`apply_delta`.

        Entity tables are REPLICATED (shared arrays across shards), so the
        write lands once and every shard's cache is reconciled against its
        own :class:`~repro.core.database.AttrDelta` stamp: entries whose
        dependency tags intersect the written ``(etype, attr)`` pairs are
        invalidated, everything else stays resident.  The router's own
        merged-result cache is epoch-invalidated.

        Args:
            etype: entity type name.
            rows / attrs: the row ids and per-attribute new values (see
                :meth:`~repro.core.database.RelationalDB.update_attrs`).
            **kw: forwarded to the engines' :meth:`~repro.core.engine
                .CountingEngine.apply_delta`.

        Returns:
            One :class:`~repro.core.engine.DeltaReport` (or ``None``) per
            shard, aligned with the shard list at application time.

        Usage::

            router.update_attrs("user", rows, {"age": new_ages})
        """
        with self._mutate_lock:
            sdb, services, engines, _ = self._snapshot()
            with self._submit_gate:
                with ExitStack() as fences:
                    # entity tables are shared arrays: nothing may be
                    # mid-batch while attribute columns move underneath
                    for svc in services:
                        fences.enter_context(svc.fence())
                    for svc in services:
                        svc.flush()        # re-entrant: fence locks held
                    deltas = sdb.update_attrs(etype, rows, attrs)
                    reports = [svc.apply_delta(d, **kw) if d is not None
                               else None
                               for svc, d in zip(services, deltas)]
                self.invalidate()
            self.metrics.inc(deltas=1)
        return reports

    def rebalance(self, shard_id: int) -> int:
        """Split one shard online: re-partition its relationship tables
        onto a NEW shard (half its hash buckets move — see
        :meth:`~repro.core.database.ShardedDatabase.split_shard`), build a
        fresh engine + service pair for both halves, and swap the
        router's shard set atomically under the epoch guard.

        No query is lost: in-flight tickets hold references to the OLD
        generation's services and shard databases (which the split left
        intact), so they drain to the correct pre-swap answers; their
        results are kept out of the router cache by the epoch bump.
        Submits arriving after the swap route against the new generation.
        Data is unchanged by a split, so answers are identical either
        way.

        Args:
            shard_id: index of the shard to split (current generation).

        Returns:
            The index of the NEW shard (== old ``n_shards``).

        Raises:
            IndexError / ValueError: see :meth:`~repro.core.database
                .ShardedDatabase.split_shard`.

        Usage::

            new_shard = router.rebalance(hot_shard)
        """
        with self._mutate_lock:
            sdb, services, engines, _ = self._snapshot()
            new_sdb = sdb.split_shard(shard_id)
            eng_a, svc_a = self._build_shard_stack(new_sdb.shards[shard_id])
            eng_b, svc_b = self._build_shard_stack(new_sdb.shards[-1])
            new_idx = new_sdb.n_shards - 1
            old_svc = services[shard_id]
            with self._lock:
                self.sdb = new_sdb
                self.engines = (engines[:shard_id] + [eng_a]
                                + engines[shard_id + 1:] + [eng_b])
                self.services = (services[:shard_id] + [svc_a]
                                 + services[shard_id + 1:] + [svc_b])
                self._results.clear()
                self._results_bytes = 0
                self._epoch += 1       # mid-flight merges settle, not cache
            self.metrics.inc(rebalances=1)
        old_svc.flush()                # drain stragglers on the old stack
        return new_idx

    # -- router-level result cache -------------------------------------------
    def invalidate(self) -> None:
        """Drop every cached merged result (e.g. after a data refresh).
        Live in-flight tickets still settle their waiters normally, but
        their (pre-invalidate) tables are NOT re-published into the
        cache — the epoch bump keeps stale data out."""
        with self._lock:
            self._results.clear()
            self._results_bytes = 0
            self._epoch += 1

    def _settle(self, key: Optional[Tuple], tab: CtTable,
                epoch: int) -> None:
        """Publish a merged result: cache it (LRU-trimmed by entry count
        AND bytes) and clear the in-flight slot so later identical
        submits hit the cache.  Results from a pre-``invalidate`` epoch
        settle their waiters but are not cached."""
        if key is None:
            return
        with self._lock:
            self._inflight.pop(key, None)
            if (epoch != self._epoch or self.cache_entries <= 0
                    or tab.nbytes > self.cache_result_bytes):
                return
            old = self._results.pop(key, None)
            if old is not None:
                self._results_bytes -= old.nbytes
            self._results[key] = tab
            self._results_bytes += tab.nbytes
            while (len(self._results) > self.cache_entries
                   or self._results_bytes > self.cache_result_bytes):
                _, dropped = self._results.popitem(last=False)
                self._results_bytes -= dropped.nbytes

    def _forget(self, key: Optional[Tuple]) -> None:
        """Drop a failed query's in-flight slot so later submits retry."""
        if key is None:
            return
        with self._lock:
            self._inflight.pop(key, None)

    # -- observability ------------------------------------------------------
    def discovery(self, **kwargs):
        """The model-discovery service running over this router (built
        lazily on first call, then shared, so concurrent clients' searches
        share one warm score memo over the sharded store).  Keyword
        arguments are forwarded to :class:`~repro.discover.service
        .DiscoveryService` on first construction and ignored afterwards.

        Usage::

            result = router.discovery().discover()
        """
        if self._discovery is None:
            from ..discover import DiscoveryService
            self._discovery = DiscoveryService(self, tracer=self.tracer,
                                               **kwargs)
        return self._discovery

    def stats(self) -> dict:
        """Health snapshot: routing counters, the per-shard service
        snapshots, and their roll-up.

        Returns:
            ``{"router": ..., "aggregate": ..., "shards": [...]}`` where
            ``aggregate`` is the :meth:`~repro.serve.metrics.ServiceMetrics
            .merged` view of all shard services plus the key-wise sum of
            the shard cache counters.
        """
        services = self._snapshot()[1]
        shard_snaps = [svc.stats() for svc in services]
        agg = ServiceMetrics.merged(
            [svc.metrics for svc in services]).snapshot()
        # deep merge: numeric leaves sum recursively, so nested sub-dicts
        # (per-tenant cache rollups) survive aggregation instead of being
        # silently dropped by a flat top-level-numeric sweep
        agg["cache"] = merge_stats_dicts(
            [snap.get("cache", {}) for snap in shard_snaps])
        out = {"router": self.metrics.snapshot(), "aggregate": agg,
               "shards": shard_snaps, "tenant": self.tenant,
               "tracer": self.tracer.snapshot()}
        if self._discovery is not None:
            out["discovery"] = self._discovery.stats()
        return out
