"""Cross-database routing: one counting service per shard, merged answers.

This is the horizontal-scaling front-end over a
:class:`~repro.core.database.ShardedDatabase`: the database no longer fits
one machine (or one device mesh), so it is hash-partitioned by root entity
and each shard runs its OWN planner/executor/cache stack behind its own
:class:`~repro.serve.service.CountingService`.  The
:class:`CountingRouter` is the thin layer clients talk to instead:

* each positive-count query is routed per
  :meth:`~repro.core.database.ShardedDatabase.route` — **fan-out** (every
  shard computes its partial table; the router sums them: sufficient
  statistics are additive over data partitions, Qian & Schulte's
  parallelisation) or **single-shard** (the query touches only replicated
  tables, so any one shard has the exact answer);
* shard services keep all of their batching machinery: a flood of router
  queries becomes per-shard signature-bucketed stacked dispatches;
* per-shard :class:`~repro.serve.metrics.ServiceMetrics` roll up into one
  aggregate view (:meth:`CountingRouter.stats`), with routing-level
  counters (:class:`~repro.serve.metrics.RouterMetrics`) on top.

Merging is exact, not approximate: counts are integer-valued and every
satisfied grounding is counted on exactly one shard (see
``ShardedDatabase.route`` for the routability condition; unroutable
queries raise :class:`~repro.core.database.NotRoutableError` instead of
returning a wrong sum).
"""

from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..core.contract import CostStats
from ..core.ct import CtTable
from ..core.database import NotRoutableError, ShardedDatabase
from ..core.engine import CountingEngine
from ..core.executors import make_executor
from ..core.variables import CtVar, LatticePoint
from .metrics import RouterMetrics, ServiceMetrics
from .service import CountingService, CountTicket

__all__ = ["CountingRouter", "RouterTicket", "NotRoutableError"]


class RouterTicket:
    """Handle for a routed query: one per-shard
    :class:`~repro.serve.service.CountTicket` per participating shard.
    ``result()`` blocks on every shard ticket and merges the tables."""

    def __init__(self, router: "CountingRouter",
                 tickets: Sequence[CountTicket], merge: bool):
        self._router = router
        self._tickets = list(tickets)
        self._merge = merge
        self._result: Optional[CtTable] = None

    @property
    def done(self) -> bool:
        return self._result is not None or all(t.done for t in self._tickets)

    def result(self, timeout: Optional[float] = None) -> CtTable:
        """The merged count table.

        Args:
            timeout: per-shard wait bound in seconds (None = wait forever).

        Returns:
            The single-database-equivalent :class:`~repro.core.ct.CtTable`:
            the sum of the per-shard tables for a fan-out query, the one
            shard's table otherwise.

        Raises:
            TimeoutError: a shard did not answer within ``timeout``.
            BaseException: whatever a shard's batch execution raised.
        """
        if self._result is None:
            tabs = [t.result(timeout) for t in self._tickets]
            out = tabs[0]
            for tab in tabs[1:]:
                out = out + tab
            if self._merge and len(tabs) > 1:
                with self._router._lock:
                    self._router.metrics.merged_tables += len(tabs)
            self._result = out
        return self._result


class CountingRouter:
    """Fan-out/merge front-end over one
    :class:`~repro.serve.service.CountingService` per database shard.

    Args:
        sdb: the partitioned database (see
            :func:`~repro.core.database.shard_database`).
        executor: backend name (``"dense"`` / ``"sparse"`` /
            ``"sparse_sharded"``) — one executor INSTANCE is built per
            shard so jit/batch caches never alias across shard databases —
            or a ready :class:`~repro.core.executors.Executor` instance,
            which is then shared by every shard engine.
        max_batch_size / max_wait_s / max_in_flight / max_pending_bytes:
            per-shard service knobs, passed through to every
            :class:`~repro.serve.service.CountingService`.
        cache_budget_bytes: per-shard ct-cache budget (each shard engine
            owns an independent cache).
        dtype: accumulation dtype for every shard engine.
        metrics: routing-level counters; defaults to a fresh
            :class:`~repro.serve.metrics.RouterMetrics`.

    Usage::

        router = CountingRouter(shard_database(db, 4), executor="sparse")
        tab = router.count(point)          # == single-DB answer, exactly
    """

    def __init__(self, sdb: ShardedDatabase, executor="sparse",
                 max_batch_size: int = 64,
                 max_wait_s: Optional[float] = None,
                 max_in_flight: int = 1024,
                 max_pending_bytes: Optional[int] = None,
                 cache_budget_bytes: Optional[int] = None,
                 dtype=jnp.float32,
                 metrics: Optional[RouterMetrics] = None):
        self.sdb = sdb
        self.metrics = metrics if metrics is not None else RouterMetrics()
        self._lock = threading.Lock()      # guards metrics bumps only
        self.engines: List[CountingEngine] = []
        self.services: List[CountingService] = []
        for shard in sdb.shards:
            ex = (executor if not isinstance(executor, str)
                  else make_executor(executor, dtype=dtype))
            eng = CountingEngine(shard, ex, CostStats(),
                                 cache_budget_bytes=cache_budget_bytes,
                                 dtype=dtype)
            self.engines.append(eng)
            self.services.append(CountingService(
                eng, max_batch_size=max_batch_size, max_wait_s=max_wait_s,
                max_in_flight=max_in_flight,
                max_pending_bytes=max_pending_bytes))

    @property
    def n_shards(self) -> int:
        return self.sdb.n_shards

    # -- client API ---------------------------------------------------------
    def submit(self, point: LatticePoint,
               keep: Optional[Sequence[CtVar]] = None) -> RouterTicket:
        """Route one positive-count query; returns immediately.

        Fan-out queries enqueue on EVERY shard service (each applies its
        own batching/backpressure); single-shard queries enqueue on the
        shard that holds the full answer.

        Args:
            point: lattice point to count (>= 1 atom).
            keep: ct-table axes; defaults to all entity/edge attributes of
                the point.

        Returns:
            A :class:`RouterTicket`; call ``.result()`` for the merged
            table.

        Raises:
            NotRoutableError: no additive merge exists for this query
                under the database's partitioning (see
                :meth:`~repro.core.database.ShardedDatabase.route`).
        """
        try:
            mode, shard = self.sdb.route(point)
        except NotRoutableError:
            with self._lock:
                self.metrics.requests += 1
                self.metrics.not_routable += 1
            raise
        with self._lock:
            self.metrics.requests += 1
            if mode == "fanout":
                self.metrics.fanout_requests += 1
            else:
                self.metrics.single_shard_requests += 1
        if mode == "fanout":
            tickets = [svc.submit(point, keep) for svc in self.services]
            return RouterTicket(self, tickets, merge=True)
        return RouterTicket(self, [self.services[shard].submit(point, keep)],
                            merge=False)

    def count(self, point: LatticePoint,
              keep: Optional[Sequence[CtVar]] = None) -> CtTable:
        """Synchronous convenience: :meth:`submit` + merged ``result()``."""
        return self.submit(point, keep).result()

    def count_many(self, queries: Sequence[Tuple[LatticePoint,
                                                 Optional[Sequence[CtVar]]]]
                   ) -> List[CtTable]:
        """Submit a whole query list, flush every shard, return merged
        tables in submission order — the per-shard services see the full
        flood at once, so same-signature queries stack per shard.

        Usage::

            tabs = router.count_many([(p, None) for p in lattice])

        Raises:
            NotRoutableError: some query has no additive merge — raised
                BEFORE anything is enqueued, so a bad query in the list
                never strands partial work on the shard queues.
        """
        for point, _ in queries:       # validate up front, enqueue nothing
            self.sdb.route(point)      # on a mixed good/bad list
        tickets = [self.submit(point, keep) for point, keep in queries]
        self.flush()
        return [t.result() for t in tickets]

    # -- scheduling ---------------------------------------------------------
    def flush(self) -> None:
        """Drain every shard service's pending queue."""
        for svc in self.services:
            svc.flush()

    def pending(self) -> int:
        """Total queries pending across all shard services."""
        return sum(svc.pending() for svc in self.services)

    # -- observability ------------------------------------------------------
    def stats(self) -> dict:
        """Health snapshot: routing counters, the per-shard service
        snapshots, and their roll-up.

        Returns:
            ``{"router": ..., "aggregate": ..., "shards": [...]}`` where
            ``aggregate`` is the :meth:`~repro.serve.metrics.ServiceMetrics
            .merged` view of all shard services plus the key-wise sum of
            the shard cache counters.
        """
        shard_snaps = [svc.stats() for svc in self.services]
        agg = ServiceMetrics.merged(
            [svc.metrics for svc in self.services]).snapshot()
        cache_agg: dict = {}
        for snap in shard_snaps:
            for k, v in snap.get("cache", {}).items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    cache_agg[k] = cache_agg.get(k, 0) + v
        agg["cache"] = cache_agg
        return {"router": self.metrics.snapshot(), "aggregate": agg,
                "shards": shard_snaps}
