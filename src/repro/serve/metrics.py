"""Service observability: per-signature-bucket latency/throughput plus the
cache hit/miss/eviction counters surfaced from :class:`~repro.core.cache
.CtCache`.

The counting service is the first layer of this repo that serves *traffic*
rather than one offline run, so its health is expressed in service terms:
how many requests short-circuited on the cache, how many were coalesced
with an identical in-flight request, how large the signature buckets
actually got (batching efficiency), and what each bucket's execution
latency/throughput looks like.  Counters are mutated from client threads,
the dispatcher thread, and router fan-out threads concurrently, so every
mutation goes through :meth:`inc`/``observe_*`` which hold the instance's
lock — plain ``+=`` on a shared counter loses increments under the
thread-switch interleavings a flood produces.

Totals hide tails, so alongside the counters each service keeps
fixed-bucket log-scale :class:`~repro.obs.hist.LatencyHistogram`\\ s
(p50/p95/p99 + max) for queue wait, bucket execution, and end-to-end
latency; the router adds shard-merge and its own end-to-end views.
Histogram merge is exactly associative, which is what lets
:meth:`ServiceMetrics.merged` roll per-shard histograms into fleet-level
percentiles without bias.

:meth:`ServiceMetrics.snapshot` and :meth:`RouterMetrics.snapshot` are
derived from ``dataclasses.fields`` — a newly added counter appears in
dashboards automatically instead of silently vanishing — and render one
JSON-able dict for dashboards/benchmarks (histograms as their
count/mean/percentile summaries).

When one front-end routes over many database shards
(:class:`~repro.serve.router.CountingRouter`), each shard's service keeps
its own :class:`ServiceMetrics`; :meth:`ServiceMetrics.merged` rolls the
per-shard counters (and their signature buckets and histograms) up into
one aggregate view, and :class:`RouterMetrics` adds the routing-level
counters on top.
"""

from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

from ..core.cache import CtCache
from ..obs.hist import LatencyHistogram


def merge_stats_dicts(snaps: Sequence[dict]) -> dict:
    """Deep-merge JSON-able stats dicts: numeric leaves SUM, nested dicts
    recurse, anything else (strings, lists, histogram summaries rendered
    as lists, ``None``) keeps the first occurrence.  Bools are identity
    flags, not counters, so they take first-wins too.

    This replaces the old top-level-numeric-only aggregation in
    :meth:`~repro.serve.router.CountingRouter.stats`, which silently
    dropped nested sub-dicts — with per-tenant rollups
    (``cache.info()["tenants"]``) nested one level down, flat aggregation
    would have erased exactly the counters tenancy adds.

    Args:
        snaps: stats dicts of the same general shape (missing keys fine).

    Returns:
        A fresh merged dict; inputs are not modified.

    Usage::

        agg = merge_stats_dicts([svc.stats()["cache"] for svc in shards])
    """
    out: dict = {}
    for snap in snaps:
        for k, v in snap.items():
            if isinstance(v, dict):
                prev = out.get(k)
                out[k] = merge_stats_dicts(
                    [prev, v] if isinstance(prev, dict) else [v])
            elif (isinstance(v, (int, float)) and not isinstance(v, bool)
                  and (k not in out
                       or (isinstance(out[k], (int, float))
                           and not isinstance(out[k], bool)))):
                base = out.get(k, 0)
                out[k] = base + v
            elif k not in out:
                out[k] = v
    return out


@dataclass
class BucketMetrics:
    """One shape-signature bucket's execution statistics (mutated only
    under the owning :class:`ServiceMetrics` lock)."""
    signature: Tuple
    queries: int = 0              # queries executed through this bucket
    batches: int = 0              # positive_batch dispatches issued
    max_batch: int = 0            # largest micro-batch seen
    exec_s: float = 0.0           # total execution wall time

    @property
    def qps(self) -> float:
        return self.queries / self.exec_s if self.exec_s > 0 else 0.0

    def as_dict(self) -> dict:
        return dict(signature=str(self.signature), queries=self.queries,
                    batches=self.batches, max_batch=self.max_batch,
                    exec_s=round(self.exec_s, 6), qps=round(self.qps, 1))


class _LockedMetrics:
    """Shared mutation/snapshot machinery for the metrics dataclasses.

    Fields are partitioned by type: ints/floats sum on merge and appear
    directly in snapshots, :class:`LatencyHistogram` fields merge
    element-wise and snapshot as percentile summaries, and ``_``-prefixed
    fields (the lock) are internal.  Subclasses handle any remaining
    fields (``buckets``) themselves.
    """

    def inc(self, **deltas) -> None:
        """Atomically add ``deltas`` to the named counter fields.

        Usage::

            metrics.inc(requests=1, cache_hits=1)
        """
        with self._lock:
            for name, d in deltas.items():
                setattr(self, name, getattr(self, name) + d)

    @classmethod
    def _numeric_fields(cls):
        return [f.name for f in dataclasses.fields(cls)
                if f.type in ("int", "float", int, float)
                and not f.name.startswith("_")]

    @classmethod
    def _hist_fields(cls):
        return [f.name for f in dataclasses.fields(cls)
                if "LatencyHistogram" in str(f.type)
                and not f.name.startswith("_")]

    def _base_snapshot(self) -> dict:
        """Field-derived snapshot core; caller holds no lock (we take it)."""
        out = {}
        with self._lock:
            for name in self._numeric_fields():
                v = getattr(self, name)
                out[name] = round(v, 6) if isinstance(v, float) else v
            for name in self._hist_fields():
                out[name] = getattr(self, name).as_dict()
        return out


@dataclass
class ServiceMetrics(_LockedMetrics):
    """Aggregate counters for one :class:`~repro.serve.service
    .CountingService` instance."""
    requests: int = 0             # submit()/submit_complete() calls
    complete_requests: int = 0    # submit_complete() calls (also in requests)
    cache_hits: int = 0           # resolved from the CtCache without queueing
    coalesced: int = 0            # merged into an identical in-flight request
    enqueued: int = 0             # entered the request queue
    admitted: int = 0             # passed the tenant admission gate
    shed: int = 0                 # rejected by admission policy "shed"
    rate_limited: int = 0         # over the token-bucket rate (shed or slept)
    throttled: int = 0            # forced drains by admission policy "queue"
    flushes: int = 0              # scheduler drains (any trigger)
    size_flushes: int = 0        # triggered by a bucket hitting max_batch_size
    wait_flushes: int = 0        # triggered by the max_wait deadline
    backpressure_flushes: int = 0  # triggered by in-flight/byte limits
    batches: int = 0              # positive_batch dispatches
    batched_queries: int = 0      # queries that went through a batch dispatch
    mobius_batches: int = 0       # batched negative-phase (Möbius) dispatches
    mobius_stacked: int = 0       # butterfly stacks transformed through them
    mobius_exec_s: float = 0.0    # total batched-transform wall time
    exec_s: float = 0.0           # total bucket execution wall time
    wait_s: float = 0.0           # total queue residency across requests
    deltas: int = 0               # apply_delta() reconciliations
    delta_updated: int = 0        # cache entries refreshed in place
    delta_invalidated: int = 0    # cache entries dropped as stale
    delta_retained: int = 0       # cache entries untouched by deltas
    buckets: Dict[Tuple, BucketMetrics] = field(default_factory=dict)
    queue_wait_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)   # per-request queue residency
    bucket_exec_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)   # per-dispatch execution latency
    e2e_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)   # submit -> result end-to-end
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def observe_mobius(self, n_stacks: int, dt: float) -> None:
        """Record one batched negative-phase dispatch covering
        ``n_stacks`` same-shape butterfly stacks."""
        with self._lock:
            self.mobius_batches += 1
            self.mobius_stacked += n_stacks
            self.mobius_exec_s += dt

    def observe_batch(self, signature: Tuple, n_queries: int,
                      dt: float) -> None:
        with self._lock:
            b = self.buckets.get(signature)
            if b is None:
                b = self.buckets[signature] = BucketMetrics(signature)
            b.queries += n_queries
            b.batches += 1
            b.max_batch = max(b.max_batch, n_queries)
            b.exec_s += dt
            self.batches += 1
            self.batched_queries += n_queries
            self.exec_s += dt
            self.bucket_exec_hist.observe(dt)

    def observe_wait(self, dt: float) -> None:
        with self._lock:
            self.wait_s += dt
            self.queue_wait_hist.observe(dt)

    def observe_e2e(self, dt: float) -> None:
        """Record one request's submit→settle latency."""
        with self._lock:
            self.e2e_hist.observe(dt)

    @property
    def qps(self) -> float:
        return self.batched_queries / self.exec_s if self.exec_s > 0 else 0.0

    @classmethod
    def merged(cls, many: Sequence["ServiceMetrics"]) -> "ServiceMetrics":
        """Roll several services' counters up into one aggregate view.

        Scalar counters and timers sum; latency histograms merge
        element-wise (exactly associative); signature buckets with the
        same signature merge (queries/batches/time sum, ``max_batch``
        takes the max).  The inputs are not modified.

        Args:
            many: the per-shard :class:`ServiceMetrics` instances.

        Returns:
            A fresh aggregate ``ServiceMetrics`` (not registered with any
            service).

        Usage::

            agg = ServiceMetrics.merged([svc.metrics for svc in shards])
        """
        out = cls()
        scalar = cls._numeric_fields()
        hists = cls._hist_fields()
        for m in many:
            with m._lock:
                for name in scalar:
                    setattr(out, name, getattr(out, name) + getattr(m, name))
                for name in hists:
                    getattr(out, name).merge(getattr(m, name))
                for sig, b in m.buckets.items():
                    agg = out.buckets.get(sig)
                    if agg is None:
                        agg = out.buckets[sig] = BucketMetrics(sig)
                    agg.queries += b.queries
                    agg.batches += b.batches
                    agg.max_batch = max(agg.max_batch, b.max_batch)
                    agg.exec_s += b.exec_s
        return out

    def snapshot(self, cache: Optional[CtCache] = None) -> dict:
        """One JSON-able health dict covering every dataclass field (new
        counters appear automatically), plus the computed ``qps``; pass
        the engine's cache to include its hit/miss/eviction/dropped
        counters alongside service counters."""
        out = self._base_snapshot()
        out["qps"] = round(self.qps, 1)
        with self._lock:
            out["buckets"] = [b.as_dict() for b in self.buckets.values()]
        if cache is not None:
            out["cache"] = cache.info()
        return out


@dataclass
class RouterMetrics(_LockedMetrics):
    """Routing-level counters of one :class:`~repro.serve.router
    .CountingRouter` — what happens *above* the per-shard services."""
    requests: int = 0             # router submit() calls
    fanout_requests: int = 0      # fanned out to every shard, tables summed
    single_shard_requests: int = 0  # answered by one shard (replicated data)
    merged_tables: int = 0        # per-shard tables merged into answers
    device_merges: int = 0        # jitted device-side merge dispatches
    partial_merges: int = 0       # overlapped folds while shards still ran
    fused_dispatches: int = 0     # cross-shard count+merge fused dispatches
    not_routable: int = 0         # rejected with NotRoutableError
    cache_hits: int = 0           # served from the router's own result cache
    coalesced: int = 0            # joined an identical in-flight fan-out
    complete_requests: int = 0    # routed complete-CT (Möbius) queries
    deltas: int = 0               # apply_delta() mutations routed to shards
    rebalances: int = 0           # online shard splits performed
    merge_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)   # per-ticket shard-merge latency
    e2e_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)   # router submit -> settled result
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def observe_merge(self, dt: float) -> None:
        with self._lock:
            self.merge_hist.observe(dt)

    def observe_e2e(self, dt: float) -> None:
        with self._lock:
            self.e2e_hist.observe(dt)

    def snapshot(self) -> dict:
        """JSON-able dict of the routing counters, derived from the
        dataclass fields (one flat level plus histogram summaries; the
        per-shard service counters live in
        :meth:`~repro.serve.router.CountingRouter.stats`)."""
        return self._base_snapshot()
