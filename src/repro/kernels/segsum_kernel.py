"""Pallas TPU kernel: edge scatter-add — the sparse executor's hop primitive.

``SparseExecutor`` reduces every positive-count hop to one scatter-add over
the raw edge list,

    out[p, d] = sum_{e : seg[e] == p} rows[e, d]        (dense-message hop)
    out[p]    = sum_{e : seg[e] == p} w[e]              (leaf hop / histogram)

where ``seg`` flattens ``(parent entity, mixed-radix attr code)`` into one
int32 segment id.  Scatter-add is hostile to the TPU memory system, so —
like :mod:`.hist_kernel` — the reduction is recast as a one-hot contraction
that runs on the MXU/VPU: the one-hot tile is built *inside* the kernel
from a ``broadcasted_iota`` comparison and never touches HBM.

What distinguishes this kernel from ``segment_hist`` is its consumer: the
flattened ``(parent, code)`` space means ``num_segments`` is routinely in
the 1e3–1e5 range while the edge axis is the long streamed dimension, and
the executor pads edge buckets with ``seg == num_segments`` (one past the
last real segment).  Out-of-range ids match no one-hot column of any tile
— padding is dropped exactly as ``jax.ops.segment_sum`` drops it, and any
spill into the padded tail rows is sliced away on return.

Grid layout: segments on the outer (parallel) grid dimension, edges on the
innermost (sequential) dimension with ``+=`` accumulation, so each output
tile stays resident in VMEM while the edge stream passes through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rows_kernel(seg_ref, rows_ref, o_ref, *, block_p: int):
    p_idx = pl.program_id(0)
    n_idx = pl.program_id(2)

    @pl.when(n_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]                                   # (Nb,)
    rows = rows_ref[...]                                 # (Nb, Db)
    base = p_idx * block_p
    col = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_p), 1)
    onehot = (seg[:, None] - base == col).astype(jnp.float32)   # (Nb, Pb)
    o_ref[...] += jnp.dot(onehot.T, rows,
                          preferred_element_type=jnp.float32)


def _ones_kernel(seg_ref, w_ref, o_ref, *, block_p: int):
    p_idx = pl.program_id(0)
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]                                   # (Nb,)
    w = w_ref[...]                                       # (Nb,)
    base = p_idx * block_p
    col = jax.lax.broadcasted_iota(jnp.int32, (seg.shape[0], block_p), 1)
    onehot = (seg[:, None] - base == col).astype(jnp.float32)   # (Nb, Pb)
    o_ref[...] += jnp.dot(w[None, :], onehot,
                          preferred_element_type=jnp.float32)   # (1, Pb)


def segment_sum_rows_pallas(seg: jnp.ndarray, rows: jnp.ndarray,
                            num_segments: int, *, block_n: int = 512,
                            block_p: int = 256, block_d: int = 256,
                            interpret: bool = True) -> jnp.ndarray:
    """``out[p, d] = sum_{e: seg[e]==p} rows[e, d]`` for ``rows`` [N, D].

    Out-of-range segment ids (the executor's ``seg == num_segments`` edge
    padding, or the -1 this wrapper pads with) contribute nothing."""
    n, d = rows.shape
    npad = ((n + block_n - 1) // block_n) * block_n if n else block_n
    dpad = ((d + block_d - 1) // block_d) * block_d
    ppad = ((num_segments + block_p - 1) // block_p) * block_p
    seg_p = jnp.pad(seg.astype(jnp.int32), (0, npad - n),
                    constant_values=-1)
    rows_p = jnp.pad(rows.astype(jnp.float32),
                     ((0, npad - n), (0, dpad - d)))

    out = pl.pallas_call(
        functools.partial(_rows_kernel, block_p=block_p),
        grid=(ppad // block_p, dpad // block_d, npad // block_n),
        in_specs=[
            pl.BlockSpec((block_n,), lambda p, dd, nn: (nn,)),
            pl.BlockSpec((block_n, block_d), lambda p, dd, nn: (nn, dd)),
        ],
        out_specs=pl.BlockSpec((block_p, block_d),
                               lambda p, dd, nn: (p, dd)),
        out_shape=jax.ShapeDtypeStruct((ppad, dpad), jnp.float32),
        interpret=interpret,
    )(seg_p, rows_p)
    return out[:num_segments, :d]


def segment_sum_ones_pallas(seg: jnp.ndarray, weights: jnp.ndarray,
                            num_segments: int, *, block_n: int = 1024,
                            block_p: int = 256,
                            interpret: bool = True) -> jnp.ndarray:
    """``out[p] = sum_{e: seg[e]==p} weights[e]`` — the weighted histogram
    (leaf hops pass all-ones weights; the sharded executor passes its 0/1
    mesh-padding mask).  Output kept 2-D ``(1, P)`` inside the kernel for
    lane alignment, squeezed on return."""
    n = int(seg.shape[0])
    npad = ((n + block_n - 1) // block_n) * block_n if n else block_n
    ppad = ((num_segments + block_p - 1) // block_p) * block_p
    seg_p = jnp.pad(seg.astype(jnp.int32), (0, npad - n),
                    constant_values=-1)
    w_p = jnp.pad(weights.astype(jnp.float32), (0, npad - n))

    out = pl.pallas_call(
        functools.partial(_ones_kernel, block_p=block_p),
        grid=(ppad // block_p, npad // block_n),
        in_specs=[
            pl.BlockSpec((block_n,), lambda p, nn: (nn,)),
            pl.BlockSpec((block_n,), lambda p, nn: (nn,)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda p, nn: (0, p)),
        out_shape=jax.ShapeDtypeStruct((1, ppad), jnp.float32),
        interpret=interpret,
    )(seg_p, w_p)
    return out[0, :num_segments]
