"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; Mosaic lowering
needs a real TPU).  On TPU deployments pass ``interpret=False`` — the
call sites in ``repro.core`` select the kernel path via the strategy's
``mobius_fn`` / config flags.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .mobius_kernel import mobius_pallas
from .hist_kernel import segment_hist_pallas
from .bdeu_kernel import bdeu_pallas
from .ref import mobius_ref, segment_hist_ref, bdeu_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def mobius(stack: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    return mobius_pallas(stack, interpret=interpret)


def mobius_nd(stack: jnp.ndarray, k: int, interpret: bool = True) -> jnp.ndarray:
    """Adapter matching `repro.core.mobius.superset_mobius`'s (2,)*k + attrs
    signature, so the kernel can be plugged in as ``Strategy.mobius_fn``."""
    lead = stack.shape[:k]
    tail = stack.shape[k:]
    import numpy as np
    d = int(np.prod(tail)) if tail else 1
    flat = stack.reshape((1 << k), d)
    out = mobius(flat, interpret=interpret)
    return out.reshape(lead + tail)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def segment_hist(codes: jnp.ndarray, values: jnp.ndarray, num_segments: int,
                 interpret: bool = True) -> jnp.ndarray:
    return segment_hist_pallas(codes, values, num_segments,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ess", "interpret"))
def bdeu(nijk: jnp.ndarray, ess: float = 1.0,
         interpret: bool = True) -> jnp.ndarray:
    return bdeu_pallas(nijk, ess=ess, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    from .attention_kernel import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)
