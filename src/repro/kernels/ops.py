"""Jit'd public wrappers for the Pallas kernels, plus the single backend
probe that decides how they lower.

Every wrapper takes ``interpret=None`` and resolves it through
:func:`default_interpret`: one probe of ``jax.default_backend()`` —
CPU → ``True`` (the Pallas interpreter; Mosaic/Triton lowering needs a
real accelerator), TPU/GPU → ``False`` (native lowering).  The
``REPRO_PALLAS_INTERPRET`` environment variable (``1``/``0``,
``true``/``false``) overrides the probe in both directions — forcing
interpret mode on an accelerator for debugging, or asserting native
lowering in a deployment where falling back to the interpreter would be
a silent 1000x regression.  Resolution happens *outside* the jitted
inner functions, so flipping the env var between calls takes effect
immediately (the bool is a static jit argument either way).

:func:`segsum_kernel_enabled` is the matching routing predicate for the
sparse executors' scatter-add hop (:mod:`.segsum_kernel`): on by default
only on accelerators (the interpreted kernel body is Python — orders of
magnitude slower than XLA's native scatter on CPU), forceable on CPU CI
with ``REPRO_SEGSUM_PALLAS=1`` for kernel-parity coverage, and always
capped at ``SEGSUM_KERNEL_MAX_SEGMENTS`` because the one-hot sweep costs
O(edges x segments) — huge flattened ``(parent, code)`` spaces stay on
``jax.ops.segment_sum``.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from .mobius_kernel import mobius_pallas
from .hist_kernel import segment_hist_pallas
from .bdeu_kernel import bdeu_pallas
from .segsum_kernel import segment_sum_ones_pallas, segment_sum_rows_pallas
from .ref import mobius_ref, segment_hist_ref, bdeu_ref

# beyond this the O(edges x segments) one-hot sweep loses to XLA scatter
SEGSUM_KERNEL_MAX_SEGMENTS = 1 << 15


def _env_flag(name: str) -> Optional[bool]:
    v = os.environ.get(name)
    if v is None or not v.strip():
        return None
    return v.strip().lower() in ("1", "true", "yes", "on")


@functools.lru_cache(maxsize=None)
def _on_accelerator() -> bool:
    try:
        return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")
    except Exception:                      # no backend at all -> interpret
        return False


def default_interpret() -> bool:
    """The one backend probe behind every kernel entry point: ``True``
    (interpreter) on CPU, ``False`` (Mosaic on TPU / Triton on GPU) on an
    accelerator; ``REPRO_PALLAS_INTERPRET`` overrides."""
    env = _env_flag("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env
    return not _on_accelerator()


def _resolve(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def segsum_kernel_enabled(num_segments: int) -> bool:
    """Should a sparse scatter-add hop with this segment space route
    through the Pallas kernel (vs ``jax.ops.segment_sum``)?"""
    if num_segments > SEGSUM_KERNEL_MAX_SEGMENTS:
        return False
    forced = _env_flag("REPRO_SEGSUM_PALLAS")
    if forced is not None:
        return forced
    return _on_accelerator()


@functools.partial(jax.jit, static_argnames=("interpret",))
def _mobius(stack: jnp.ndarray, interpret: bool) -> jnp.ndarray:
    return mobius_pallas(stack, interpret=interpret)


def mobius(stack: jnp.ndarray,
           interpret: Optional[bool] = None) -> jnp.ndarray:
    return _mobius(stack, interpret=_resolve(interpret))


def mobius_nd(stack: jnp.ndarray, k: int,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Adapter matching `repro.core.mobius.superset_mobius`'s (2,)*k + attrs
    signature, so the kernel can be plugged in as ``Strategy.mobius_fn``."""
    lead = stack.shape[:k]
    tail = stack.shape[k:]
    import numpy as np
    d = int(np.prod(tail)) if tail else 1
    flat = stack.reshape((1 << k), d)
    out = mobius(flat, interpret=interpret)
    return out.reshape(lead + tail)


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _segment_hist(codes: jnp.ndarray, values: jnp.ndarray,
                  num_segments: int, interpret: bool) -> jnp.ndarray:
    return segment_hist_pallas(codes, values, num_segments,
                               interpret=interpret)


def segment_hist(codes: jnp.ndarray, values: jnp.ndarray, num_segments: int,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    return _segment_hist(codes, values, num_segments,
                         interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _edge_segment_sum(seg: jnp.ndarray, rows: jnp.ndarray,
                      num_segments: int, interpret: bool) -> jnp.ndarray:
    return segment_sum_rows_pallas(seg, rows, num_segments,
                                   interpret=interpret)


def edge_segment_sum(seg: jnp.ndarray, rows: jnp.ndarray, num_segments: int,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Kernel-backed ``out[p, :] = sum_{e: seg[e]==p} rows[e, :]`` — the
    sparse executor's dense-message hop."""
    return _edge_segment_sum(seg, rows, num_segments,
                             interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("num_segments", "interpret"))
def _ones_segment_sum(seg: jnp.ndarray, weights: jnp.ndarray,
                      num_segments: int, interpret: bool) -> jnp.ndarray:
    return segment_sum_ones_pallas(seg, weights, num_segments,
                                   interpret=interpret)


def ones_segment_sum(seg: jnp.ndarray, weights: jnp.ndarray,
                     num_segments: int,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Kernel-backed weighted histogram ``out[p] = sum_{e: seg[e]==p}
    w[e]`` — the sparse executor's leaf hop and code histogram."""
    return _ones_segment_sum(seg, weights, num_segments,
                             interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("ess", "interpret"))
def _bdeu(nijk: jnp.ndarray, ess: float, interpret: bool) -> jnp.ndarray:
    return bdeu_pallas(nijk, ess=ess, interpret=interpret)


def bdeu(nijk: jnp.ndarray, ess: float = 1.0,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    return _bdeu(nijk, ess=ess, interpret=_resolve(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention(q, k, v, causal: bool, block_q: int, block_k: int,
                     interpret: bool):
    from .attention_kernel import flash_attention_pallas
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=interpret)


def flash_attention(q, k, v, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: Optional[bool] = None):
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=_resolve(interpret))
