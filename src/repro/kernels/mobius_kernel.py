"""Pallas TPU kernel: superset Möbius transform (the Möbius Join core).

TPU adaptation: instead of k strided butterfly passes (pointer-chasing,
VPU-bound on sublanes), the whole transform over the 2^k relationship
configurations is a single small matmul by the precomputed transform matrix

    T[A, S] = (-1)^{|S \\ A|}  if S >= A  else 0      (bitmask order)

so the kernel is ``out = T @ X`` with X = [2^k, D] resident per D-tile — an
MXU op with perfect reuse of T.  For k <= 8 T is at most 256x256 (256 KiB
f32), far under VMEM.  The attribute axis D is tiled across the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def mobius_matrix(k: int, dtype=np.float32) -> np.ndarray:
    """Dense superset-Möbius transform matrix over bitmasks of length k."""
    r = 1 << k
    t = np.zeros((r, r), dtype=dtype)
    for a in range(r):
        for s in range(r):
            if (a & s) == a:  # S superset of A
                t[a, s] = (-1.0) ** bin(s & ~a).count("1")
    return t


def _mobius_kernel(t_ref, x_ref, o_ref):
    t = t_ref[...]
    x = x_ref[...]
    o_ref[...] = jnp.dot(t, x, preferred_element_type=jnp.float32)


def mobius_pallas(stack: jnp.ndarray, *, block_d: int = 512,
                  interpret: bool = True) -> jnp.ndarray:
    """Apply the superset Möbius transform to a [R=2^k, D] stack."""
    r, d = stack.shape
    k = r.bit_length() - 1
    assert 1 << k == r, "leading dim must be 2^k"
    rp = max(8, r)                       # sublane-align tiny stacks
    t = np.eye(rp, dtype=np.float32)
    t[:r, :r] = mobius_matrix(k)
    dp = ((d + block_d - 1) // block_d) * block_d
    x = stack.astype(jnp.float32)
    if rp != r or dp != d:
        x = jnp.pad(x, ((0, rp - r), (0, dp - d)))

    out = pl.pallas_call(
        _mobius_kernel,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((rp, rp), lambda i: (0, 0)),        # T resident
            pl.BlockSpec((rp, block_d), lambda i: (0, i)),   # X tile
        ],
        out_specs=pl.BlockSpec((rp, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(t), x)
    return out[:r, :d]
