"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln


def mobius_ref(stack: jnp.ndarray) -> jnp.ndarray:
    """Superset Möbius transform on a [R=2^k, D] stack: replace the
    "unconstrained" slot (bit=0) with "false" via x0 <- x0 - x1 per bit."""
    r, d = stack.shape
    k = r.bit_length() - 1
    assert 1 << k == r, "leading dim must be a power of two"
    x = stack.reshape((2,) * k + (d,))
    for i in range(k):
        x0 = jnp.take(x, 0, axis=i) - jnp.take(x, 1, axis=i)
        x1 = jnp.take(x, 1, axis=i)
        x = jnp.stack([x0, x1], axis=i)
    return x.reshape(r, d)


def segment_hist_ref(codes: jnp.ndarray, values: jnp.ndarray,
                     num_segments: int) -> jnp.ndarray:
    """Weighted histogram / segment-sum: out[p, d] = sum_{n: codes[n]=p} values[n, d]."""
    return jax.ops.segment_sum(values, codes, num_segments=num_segments)


def edge_segment_sum_ref(seg: jnp.ndarray, rows: jnp.ndarray,
                         num_segments: int) -> jnp.ndarray:
    """Sparse hop scatter-add: out[p, d] = sum_{e: seg[e]=p} rows[e, d];
    out-of-range segment ids (edge-bucket padding) are dropped."""
    return jax.ops.segment_sum(rows, seg, num_segments=num_segments)


def ones_segment_sum_ref(seg: jnp.ndarray, weights: jnp.ndarray,
                         num_segments: int) -> jnp.ndarray:
    """Weighted histogram: out[p] = sum_{e: seg[e]=p} weights[e]."""
    return jax.ops.segment_sum(weights, seg, num_segments=num_segments)


def bdeu_ref(nijk: jnp.ndarray, ess: float, q: int, r: int) -> jnp.ndarray:
    """BDeu log marginal likelihood over N_ijk [Q, R] (Q may be padded with
    zero rows and R with zero columns — both contribute exactly 0)."""
    a_j = ess / q
    a_jk = ess / (q * r)
    nij = jnp.sum(nijk, axis=1)
    per_j = (gammaln(a_j) - gammaln(nij + a_j)
             + jnp.sum(gammaln(nijk + a_jk) - gammaln(a_jk), axis=1))
    return jnp.sum(per_j)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Oracle for the flash-attention kernel: q/k/v [B,S,H,hd], H already
    broadcast (GQA groups expanded by the caller)."""
    b, sq, h, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        m = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(m[None, None], s, -1e30)
    w = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
