"""Pallas TPU kernel: flash-attention forward (the LM-stack hot spot).

Online-softmax formulation: the grid walks (batch*kv-head, q-block, kv-block)
with the kv axis innermost/sequential; running max ``m``, normaliser ``l``
and the unnormalised accumulator live in VMEM scratch across kv iterations,
so the [Sq, Skv] score matrix never touches HBM — exactly the traffic the
HLO-level remat path (models/attention.py one_block + jax.checkpoint) still
pays at fusion boundaries; see EXPERIMENTS.md §Perf H1 it.2.

Layout: q is presented per (b, kv-head) as [G*hd] fused rows (G = grouped
query heads) so GQA reuses one kv tile across its query group inside the
same kernel instance.  Block shapes are MXU-aligned: q rows x d and kv rows
x d tiles with d = head_dim (<= 128 for all assigned archs; padded to 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [Bq, d]
    k = k_ref[0]                                   # [Bk, d]
    v = v_ref[0]                                   # [Bk, d]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]                            # [Bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # [Bq, Bk]
    corr = jnp.exp(m_prev - m_new)                 # [Bq, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, block_q: int = 256,
                           block_k: int = 256,
                           interpret: bool = True) -> jnp.ndarray:
    """q [B, Sq, H, hd], k/v [B, Skv, H, hd] (kv heads already broadcast to
    H — GQA callers repeat or reshape groups).  Returns [B, Sq, H, hd]."""
    b, sq, h, hd = q.shape
    _, skv, _, _ = k.shape
    scale = hd ** -0.5

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    sq_p = ((sq + bq - 1) // bq) * bq
    skv_p = ((skv + bk - 1) // bk) * bk
    hd_p = max(128, ((hd + 127) // 128) * 128) if not interpret else hd

    def prep(x, s_p):
        x = jnp.moveaxis(x, 2, 1).reshape(b * h, x.shape[1], hd)
        return jnp.pad(x, ((0, 0), (0, s_p - x.shape[1]), (0, hd_p - hd)))

    qf = prep(q, sq_p)
    kf = prep(k, skv_p)
    vf = prep(v, skv_p)
    # padded kv rows must never win the softmax: rely on causal mask for
    # causal; for non-causal, bias padded keys to NEG_INF via k = -inf trick
    if not causal and skv_p != skv:
        pad_mask = jnp.arange(skv_p) >= skv
        kf = jnp.where(pad_mask[None, :, None], 0.0, kf)
        vf = jnp.where(pad_mask[None, :, None], 0.0, vf)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk),
        grid=(b * h, sq_p // bq, skv_p // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd_p), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, bk, hd_p), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, bk, hd_p), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd_p), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, hd_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # normaliser l
            pltpu.VMEM((bq, hd_p), jnp.float32),   # unnormalised accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :sq, :hd].reshape(b, h, sq, hd)
    return jnp.moveaxis(out, 1, 2)
