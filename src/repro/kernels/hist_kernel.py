"""Pallas TPU kernel: weighted segment histogram (the JOIN hop inner loop).

``out[p, d] = sum_{n : codes[n] == p} values[n, d]``

TPU adaptation: scatter-add is hostile to the TPU memory system, so the hop
is recast as a one-hot matmul — ``out = OneHot(codes)^T @ values`` — which
runs on the MXU.  The one-hot tile is materialised *inside* the kernel from a
``broadcasted_iota`` comparison (never in HBM).  Grid: (segments x D x N)
tiles with accumulation over the N (sequential, innermost) dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(codes_ref, vals_ref, o_ref, *, block_p: int):
    n_idx = pl.program_id(2)
    p_idx = pl.program_id(0)

    @pl.when(n_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = codes_ref[...]                                  # (Nc,)
    vals = vals_ref[...]                                    # (Nc, Db)
    base = p_idx * block_p
    seg = jax.lax.broadcasted_iota(jnp.int32, (codes.shape[0], block_p), 1)
    onehot = (codes[:, None] - base == seg).astype(jnp.float32)  # (Nc, Pb)
    o_ref[...] += jnp.dot(onehot.T, vals, preferred_element_type=jnp.float32)


def segment_hist_pallas(codes: jnp.ndarray, values: jnp.ndarray,
                        num_segments: int, *, block_n: int = 512,
                        block_p: int = 256, block_d: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    """Weighted histogram of ``values`` [N, D] into ``num_segments`` rows.

    Out-of-range codes (e.g. -1 padding) are dropped — they match no one-hot
    column."""
    n, d = values.shape
    npad = ((n + block_n - 1) // block_n) * block_n
    dpad = ((d + block_d - 1) // block_d) * block_d
    ppad = ((num_segments + block_p - 1) // block_p) * block_p
    codes_p = jnp.pad(codes.astype(jnp.int32), (0, npad - n),
                      constant_values=-1)
    vals_p = jnp.pad(values.astype(jnp.float32),
                     ((0, npad - n), (0, dpad - d)))

    out = pl.pallas_call(
        functools.partial(_hist_kernel, block_p=block_p),
        grid=(ppad // block_p, dpad // block_d, npad // block_n),
        in_specs=[
            pl.BlockSpec((block_n,), lambda p, dd, nn: (nn,)),
            pl.BlockSpec((block_n, block_d), lambda p, dd, nn: (nn, dd)),
        ],
        out_specs=pl.BlockSpec((block_p, block_d), lambda p, dd, nn: (p, dd)),
        out_shape=jax.ShapeDtypeStruct((ppad, dpad), jnp.float32),
        interpret=interpret,
    )(codes_p, vals_p)
    return out[:num_segments, :d]
