"""Pallas TPU kernel: BDeu family-score reduction.

The scoring hot loop is an lgamma-heavy reduction over N_ijk [Q, R] with Q =
parent configurations (large for big families) and R = child arity (small).
Zero-padded rows/columns contribute exactly 0 to the score (lgamma terms
cancel), so padding needs no masks.

Grid tiles Q; each tile computes its partial score into its slot of a
[num_blocks] partials vector, summed by the wrapper.  All transcendentals run
on the VPU from VMEM-resident tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lgamma(x):
    return jax.lax.lgamma(x)


def _bdeu_kernel(nijk_ref, o_ref, *, a_j: float, a_jk: float, r_true: int):
    nijk = nijk_ref[...]                                     # (Qb, Rp)
    nij = jnp.sum(nijk, axis=1)
    # mask padded child-value columns to an exact 0 contribution (the lgamma
    # approximation is not bitwise-stable enough for cancellation to be exact)
    col = jax.lax.broadcasted_iota(jnp.int32, nijk.shape, 1)
    terms = jnp.where(col < r_true,
                      _lgamma(nijk + a_jk) - _lgamma(jnp.full_like(nijk, a_jk)),
                      0.0)
    per_j = (_lgamma(jnp.full_like(nij, a_j)) - _lgamma(nij + a_j)
             + jnp.sum(terms, axis=1))
    o_ref[0, 0] = jnp.sum(per_j)


def bdeu_pallas(nijk: jnp.ndarray, ess: float = 1.0, *,
                block_q: int = 512, interpret: bool = True) -> jnp.ndarray:
    """BDeu score of N_ijk [Q, R]; returns a scalar f32."""
    q, r = nijk.shape
    a_j = float(ess / q)
    a_jk = float(ess / (q * r))
    qpad = ((q + block_q - 1) // block_q) * block_q
    rpad = ((r + 127) // 128) * 128
    x = jnp.pad(nijk.astype(jnp.float32), ((0, qpad - q), (0, rpad - r)))
    nblk = qpad // block_q

    partials = pl.pallas_call(
        functools.partial(_bdeu_kernel, a_j=a_j, a_jk=a_jk, r_true=r),
        grid=(nblk,),
        in_specs=[pl.BlockSpec((block_q, rpad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        interpret=interpret,
    )(x)
    # padded rows contribute lgamma(a_j)-lgamma(a_j)+R*0 = 0; padded columns
    # contribute lgamma(a_jk)-lgamma(a_jk) = 0 -> partial sums are exact.
    return jnp.sum(partials)
