"""Version-tolerant wrappers for jax APIs that moved between releases.

The repo targets the modern surface (``jax.shard_map``, ``jax.sharding
.AxisType``, ``jax.set_mesh``); this module backfills each name from the
experimental location when running on an older jax (e.g. 0.4.x, where
``shard_map`` still lives in ``jax.experimental.shard_map`` and takes
``check_rep`` instead of ``check_vma``).  Import from here, never from jax
directly, for any of these symbols.
"""

from __future__ import annotations

import inspect
from typing import Any

import jax

try:                                     # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:                      # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)

# Feature probes for APIs with no sensible fallback: callers (and tests)
# gate sharded code paths on these instead of crashing mid-trace.
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_SET_MESH = hasattr(jax, "set_mesh") or hasattr(jax.sharding, "set_mesh")
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def shard_map(f, **kwargs):
    """``jax.shard_map`` with kwarg renames smoothed over.

    ``check_vma`` (new name) falls back to ``check_rep`` (old name); kwargs
    the installed jax does not know are dropped rather than TypeError'd.
    """
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    kwargs = {k: v for k, v in kwargs.items() if k in _SHARD_MAP_PARAMS}
    return _shard_map(f, **kwargs)


def get_abstract_mesh():
    """The ambient mesh set by ``set_mesh`` — native on new jax, the
    module-level emulation (installed below) on old jax."""
    return jax.sharding.get_abstract_mesh()


def make_mesh(axis_shapes, axis_names, axis_types=None) -> Any:
    """``jax.make_mesh`` minus the ``axis_types`` kwarg on old jax."""
    if axis_types is not None and HAS_AXIS_TYPE:
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


# ---------------------------------------------------------------------------
# Backfill the modern ambient-mesh API onto the jax namespace when missing,
# so call sites (and tests) written against it run unchanged on old jax.
# The emulation keeps a process-local current mesh: ``set_mesh`` is a
# context manager that also enters the concrete mesh (the 0.4.x resource
# env), and ``get_abstract_mesh`` returns it (a concrete Mesh quacks like
# an AbstractMesh for the attributes used here: .empty/.axis_names/.shape).
# ---------------------------------------------------------------------------

if not HAS_ABSTRACT_MESH or not HAS_SET_MESH:
    import contextlib

    _AMBIENT_MESH = []

    @contextlib.contextmanager
    def _set_mesh(mesh):
        _AMBIENT_MESH.append(mesh)
        try:
            with mesh:
                yield mesh
        finally:
            _AMBIENT_MESH.pop()

    def _get_abstract_mesh():
        return _AMBIENT_MESH[-1] if _AMBIENT_MESH else None

    if not hasattr(jax.sharding, "set_mesh"):
        jax.sharding.set_mesh = _set_mesh
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = jax.sharding.set_mesh
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = _get_abstract_mesh

if not HAS_AXIS_TYPE:
    class _AxisTypeNS:
        """Placeholder enum; values are accepted (and ignored) by the
        make_mesh wrapper below."""
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisTypeNS

    _orig_make_mesh = jax.make_mesh

    def _make_mesh_compat(axis_shapes, axis_names, *args, **kwargs):
        kwargs.pop("axis_types", None)
        return _orig_make_mesh(axis_shapes, axis_names)

    jax.make_mesh = _make_mesh_compat
