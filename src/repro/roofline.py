"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs        / (chips x 197 TF/s bf16)
    memory     = HLO_bytes        / (chips x 819 GB/s HBM)
    collective = collective_bytes / (chips x 50 GB/s ICI)

``cost_analysis`` supplies FLOPs and bytes.  Collective traffic is not in
cost_analysis: we parse the (post-SPMD, per-device) optimized HLO and sum the
moved bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, with the standard per-chip link-traffic factors
(all-reduce counts ~2x its payload: reduce-scatter + all-gather phases).
Shapes in the per-device module are already per-chip.
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_LINK_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    bsz = _DTYPE_BYTES.get(dtype)
    if bsz is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * bsz


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum per-op-kind output bytes (per-device) weighted by link factor."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0.0, "link_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"(?:\(?)\s*(\w+)\[([\d,]*)\]", rhs)
        if m is None:
            continue
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # counted at -start
        # output may be a tuple: sum all shapes on the rhs head
        shapes = _SHAPE_RE.findall(rhs.split("(", 1)[0])
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
        out[kind]["link_bytes"] += nbytes * _LINK_FACTOR[kind]
    return out


def roofline_terms(cost: Dict[str, float], collectives: Dict[str, Dict],
                   chips: int, *, per_device_cost: bool = True,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   ici_bw: float = 50e9) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    if not per_device_cost:
        flops /= chips
        nbytes /= chips
    coll_bytes = sum(v["link_bytes"] for v in collectives.values())
    t_compute = flops / peak_flops
    t_memory = nbytes / hbm_bw
    t_coll = coll_bytes / ici_bw
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "flops_per_chip": flops, "bytes_per_chip": nbytes,
        "collective_bytes_per_chip": coll_bytes,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": dom[1],
        "t_bound_s": dom[0],
    }


def model_flops(cfg, shape, chips: int) -> Dict[str, float]:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D; D = tokens processed.

    For decode shapes, one token per sequence is processed per step."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.tokens
        flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.tokens
        flops = 2.0 * n_active * tokens       # forward only
    else:
        tokens = shape.global_batch           # one new token per sequence
        flops = 2.0 * n_active * tokens
    return {"model_flops_total": flops, "model_flops_per_chip": flops / chips,
            "tokens": tokens}
