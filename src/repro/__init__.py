"""Paper reproduction package.

Importing the package installs the jax version-compat backfills (see
:mod:`repro.compat`) before any module touches the moved APIs.
"""

from . import compat  # noqa: F401  (side effect: jax API backfills)
