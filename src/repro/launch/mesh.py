"""Production meshes.

Defined as functions (importing this module never touches jax device state).
Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16); the ``pod`` axis extends FSDP/data-parallel
sharding across the DCN boundary (gradients reduce over pod+data).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — used by smoke tests
    and the CPU examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
