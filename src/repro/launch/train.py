"""End-to-end training launcher.

Runs any ``--arch`` (full or reduced config) on the local mesh with the full
substrate: sharded params, microbatch accumulation, AdamW/Adafactor,
checkpoint/resume (fault tolerance), optional int8 gradient compression, and
the deterministic sharded data pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.checkpoint.store import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticCorpus
from repro.launch.mesh import make_local_mesh
from repro.models.config import ShapeConfig
from repro.models.model import build_model
from repro.optim.adamw import OptConfig, make_optimizer
from repro.optim.compress import make_compressor
from repro.train.sharding import batch_shardings, param_shardings
from repro.train.step import init_train_state, make_train_step


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b",
                    help=f"one of {ARCHS} or a register_config()'d name")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"],
                    default="adamw")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_reduced(args.arch) if args.reduced else get_config(args.arch))
    cfg = cfg.replace(microbatch=args.microbatch)
    if cfg.embeds_input or cfg.enc_dec:
        print(f"note: {args.arch} uses a stub frontend; training on synthetic "
              "tokens routed through the stub inputs")
    model = build_model(cfg)
    mesh = make_local_mesh(args.model_axis)

    opt = make_optimizer(OptConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5),
        state_dtype=cfg.opt_state_dtype, kind=args.optimizer))
    compress = make_compressor() if args.compress else None

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    corpus = SyntheticCorpus(dcfg)

    with jax.sharding.set_mesh(mesh):
        state = init_train_state(model, opt, jax.random.PRNGKey(args.seed))
        start_step = 0
        if args.ckpt_dir and args.resume:
            ls = latest_step(args.ckpt_dir)
            if ls is not None:
                like = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
                state = restore_checkpoint(args.ckpt_dir, ls, like)
                start_step = ls
                print(f"resumed from step {ls}")

        step_fn = jax.jit(make_train_step(model, opt, compress=compress),
                          donate_argnums=(0,))
        pf = Prefetcher(corpus, start_step=start_step)
        losses = []
        t0 = time.time()
        try:
            for i in range(start_step, args.steps):
                step_idx, host_batch = next(pf)
                assert step_idx == i
                batch = make_model_batch(cfg, host_batch)
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                if i % args.log_every == 0 or i == args.steps - 1:
                    dt = time.time() - t0
                    print(f"step {i:5d}  loss {loss:8.4f}  "
                          f"lr {float(metrics['lr']):.2e}  {dt:6.1f}s",
                          flush=True)
                if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                    save_checkpoint(args.ckpt_dir, i + 1, state)
        finally:
            pf.close()
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, state)
    return losses


def make_model_batch(cfg, host_batch):
    """Adapt the token pipeline to each family's input layout (stub
    frontends get random-projected token embeddings)."""
    tokens = jnp.asarray(host_batch["tokens"])
    labels = jnp.asarray(host_batch["labels"])
    b, s = tokens.shape
    if cfg.enc_dec:
        key = jax.random.fold_in(jax.random.PRNGKey(7), 0)
        frames = jax.random.normal(key, (b, cfg.enc_frames, cfg.d_model),
                                   jnp.bfloat16)
        return {"frames": frames, "tokens": tokens, "labels": labels}
    if cfg.embeds_input:
        # stub frontend: deterministic pseudo-embedding of the token ids
        key = jax.random.PRNGKey(11)
        table = jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.bfloat16)
        batch = {"embeds": table[tokens], "labels": labels}
        if cfg.rope == "mrope":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32), (3, b, s))
        return batch
    return {"tokens": tokens, "labels": labels}


if __name__ == "__main__":
    run()
