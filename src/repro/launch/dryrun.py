import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes and extract memory / cost / collective statistics.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first initialisation (see the brief).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

One cell per process is recommended (the driver script does this) — XLA's
compile arena for 512 fake devices is only reclaimed at process exit."""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import decode_batch, prefill_batch, train_batch
from repro.models.config import SHAPES, shape_cells
from repro.models.model import build_model
from repro.optim.adamw import OptConfig, make_optimizer
from repro.hlo_analysis import analyze as analyze_hlo
from repro.roofline import model_flops, parse_collectives, roofline_terms
from repro.train.sharding import (batch_shardings, cache_shardings,
                                  logits_sharding, param_shardings)
from repro.train.step import (init_train_state, make_decode_step,
                              make_prefill_step, make_train_step)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)

    with jax.sharding.set_mesh(mesh):
        if shape.kind == "train":
            opt = make_optimizer(OptConfig(state_dtype=cfg.opt_state_dtype))
            step = make_train_step(model, opt)
            state = jax.eval_shape(
                lambda: init_train_state(model, opt, jax.random.PRNGKey(0)))
            state_sh = param_shardings(state, mesh)
            batch = train_batch(cfg, shape)
            b_sh = batch_shardings(batch, mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_sh = param_shardings(params, mesh)
            batch = prefill_batch(cfg, shape)
            b_sh = batch_shardings(batch, mesh)
            cache_abs = jax.eval_shape(step, params, batch)[1]
            c_sh = cache_shardings(cache_abs, mesh)
            out_sh = (logits_sharding(mesh, shape.global_batch, cfg.vocab), c_sh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(params, batch)
        else:  # decode
            step = make_decode_step(model, mesh=mesh, seq_sharded=True)
            params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
            p_sh = param_shardings(params, mesh)
            batch, cache = decode_batch(cfg, shape)
            b_sh = batch_shardings(batch, mesh)
            c_sh = cache_shardings(cache, mesh)
            out_sh = (logits_sharding(mesh, shape.global_batch, cfg.vocab), c_sh)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=out_sh, donate_argnums=(1,))
            lowered = jitted.lower(params, cache, batch)

        compiled = lowered.compile()
    return cfg, shape, mesh, lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path | None, overrides: dict | None = None,
             tag: str = "") -> dict:
    t0 = time.time()
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
           "tag": tag}
    try:
        cfg, shape, mesh, lowered, compiled = lower_cell(
            arch, shape_name, multi_pod, overrides)
        chips = mesh.size
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)          # static op sites (reference)
        # trip-count-aware totals (XLA cost_analysis visits while bodies once)
        hlo_totals = analyze_hlo(hlo)
        terms = roofline_terms(
            {"flops": hlo_totals["flops"],
             "bytes accessed": hlo_totals["bytes"]},
            {"all": {"link_bytes": hlo_totals["coll_link_bytes"],
                     "count": 0, "bytes": hlo_totals["coll_link_bytes"]}},
            chips)
        mf = model_flops(cfg, shape, chips)
        useful = (mf["model_flops_per_chip"]
                  / max(terms["flops_per_chip"], 1.0))
        rec.update(
            ok=True,
            chips=chips,
            compile_s=round(time.time() - t0, 1),
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            cost={k: cost.get(k) for k in ("flops", "bytes accessed")},
            hlo_totals={k: v for k, v in hlo_totals.items()},
            collectives=coll,
            roofline=terms,
            model_flops=mf,
            useful_flops_ratio=useful,
        )
        print(f"[dryrun] OK   {arch} x {shape_name} x {mesh_name} "
              f"({rec['compile_s']}s) bottleneck={terms['bottleneck']}",
              flush=True)
    except Exception as e:  # record failures — they are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: "
              f"{rec['error'][:200]}", flush=True)
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"-{tag}" if tag else ""
        fn = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        fn.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="comma k=v model-config overrides (perf experiments)")
    args = ap.parse_args()

    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    out = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    n_fail = 0
    for arch, shp in cells:
        for mp in meshes:
            rec = run_cell(arch, shp, mp, out, overrides or None, args.tag)
            n_fail += 0 if rec["ok"] else 1
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
