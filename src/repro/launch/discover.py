import os
if os.environ.get("REPRO_DRYRUN") == "1":          # before any jax import
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Distributed statistical-relational model discovery (the paper's workload).

Two modes:

* default — run end-to-end discovery (lattice -> HYBRID counting -> BDeu
  hill-climb) on the LOCAL mesh with the edge tables sharded over ``data``
  (``core/distributed.py``); prints the learned model + counting stats.

      PYTHONPATH=src python -m repro.launch.discover --db IMDb --scale 0.1

* --dryrun (env REPRO_DRYRUN=1) — lower + compile the sharded JOIN-sweep hop
  (the positive ct-table contraction, the JOIN-problem kernel the paper
  pre-counts) for a Visual-Genome-scale edge table on the production mesh,
  and report the three roofline terms.  This is the §Perf H3 mesh cell.

      REPRO_DRYRUN=1 PYTHONPATH=src python -m repro.launch.discover \
          --dryrun --edges 15833273 --entities 200000 --dvals 48
"""

import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.database import PAPER_DATASETS, paper_benchmark_db
from repro.core.distributed import sharded_positive_ct, _sharded_hop
from repro.core.search import discover_model
from repro.core.strategies import make_strategy
from repro.hlo_analysis import analyze as analyze_hlo
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.roofline import roofline_terms


def run_local(db_name: str, scale: float) -> None:
    db = paper_benchmark_db(db_name, scale=scale)
    mesh = make_local_mesh()
    print(f"database {db_name} (scale {scale}): {db.total_rows} rows; "
          f"mesh {dict(mesh.shape)}")
    # distributed JOIN sweep for every lattice point, then standard HYBRID
    from repro.core.variables import build_lattice
    lattice = build_lattice(db.schema, 2)
    strat = make_strategy("HYBRID")
    with jax.sharding.set_mesh(mesh):
        models, strat = discover_model(db, strat, max_chain_length=2,
                                       max_parents=2)
    st = strat.stats.as_dict()
    for point, model in models.items():
        print(f"  [{','.join(sorted(point.rels))}] score={model.score:.1f} "
              f"edges={len(model.edges())}")
    print({k: round(v, 3) if isinstance(v, float) else v
           for k, v in st.items()})


def run_dryrun(edges: int, entities: int, dvals: int, multi_pod: bool,
               out_dir: str) -> dict:
    """Lower the sharded join hop: (child one-hot msgs over `entities` rows)
    gathered through `edges` edge rows, expanded by a card-4 edge attribute,
    segment-summed to parents, psum over data.  Shapes are VG-scale."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    axis = "data"
    nsh = mesh.shape[axis]
    pad = ((edges + nsh - 1) // nsh) * nsh
    v_axis = "model" if dvals % mesh.shape["model"] == 0 else None
    hop = _sharded_hop(mesh, axis, entities, 1, jnp.float32,
                       value_axis=v_axis)

    cm = jax.ShapeDtypeStruct((entities, dvals), jnp.float32)
    gi = jax.ShapeDtypeStruct((pad,), jnp.int32)
    si = jax.ShapeDtypeStruct((pad,), jnp.int32)
    w = jax.ShapeDtypeStruct((pad,), jnp.float32)
    hot = jax.ShapeDtypeStruct((pad, 5), jnp.float32)

    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(hop).lower(cm, gi, si, w, hot)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    totals = analyze_hlo(hlo)
    terms = roofline_terms(
        {"flops": totals["flops"], "bytes accessed": totals["bytes"]},
        {"all": {"link_bytes": totals["coll_link_bytes"], "count": 0,
                 "bytes": totals["coll_link_bytes"]}},
        mesh.size)
    rec = {
        "cell": "counting-join-sweep",
        "edges": edges, "entities": entities, "dvals": dvals,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "chips": mesh.size,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "roofline": terms,
    }
    print(json.dumps(rec, indent=1, default=str))
    if out_dir:
        p = Path(out_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"counting__{rec['mesh']}.json").write_text(
            json.dumps(rec, indent=1, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--db", choices=PAPER_DATASETS, default="UW")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--edges", type=int, default=15_833_273)
    ap.add_argument("--entities", type=int, default=200_000)
    ap.add_argument("--dvals", type=int, default=48)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    if args.dryrun:
        if os.environ.get("REPRO_DRYRUN") != "1":
            print("set REPRO_DRYRUN=1 (before python starts) for --dryrun",
                  file=sys.stderr)
            return 2
        run_dryrun(args.edges, args.entities, args.dvals, args.multi_pod,
                   args.out)
    else:
        run_local(args.db, args.scale)
    return 0


if __name__ == "__main__":
    sys.exit(main())
