"""Input specifications per (architecture x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, no device allocation) for the dry-run; ``concrete=True``
materialises small real arrays for smoke tests.  Modality frontends are
stubs per the brief: VLM cells receive patch embeddings + M-RoPE ids, audio
cells receive precomputed frame embeddings."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.model import build_model


def _mk(shape, dtype, concrete: bool, kind: str = "zeros", vocab: int = 0):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if kind == "tokens":
        rng = np.random.default_rng(0)
        return jnp.asarray(rng.integers(0, vocab, size=shape, dtype=np.int32))
    if kind == "normal":
        rng = np.random.default_rng(1)
        return jnp.asarray(rng.normal(0, 1, size=shape).astype(np.float32),
                           dtype=dtype)
    return jnp.zeros(shape, dtype)


def train_batch(cfg: ModelConfig, shape: ShapeConfig,
                concrete: bool = False) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    bt: Dict[str, Any] = {}
    if cfg.enc_dec:
        bt["frames"] = _mk((b, cfg.enc_frames, cfg.d_model), jnp.bfloat16,
                           concrete, "normal")
        bt["tokens"] = _mk((b, s), jnp.int32, concrete, "tokens", cfg.vocab)
    elif cfg.embeds_input:
        bt["embeds"] = _mk((b, s, cfg.d_model), jnp.bfloat16, concrete, "normal")
        if cfg.rope == "mrope":
            # stub M-RoPE ids: sequential text positions on all three streams
            bt["positions"] = (
                jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
                if concrete else jax.ShapeDtypeStruct((3, b, s), jnp.int32))
    else:
        bt["tokens"] = _mk((b, s), jnp.int32, concrete, "tokens", cfg.vocab)
    bt["labels"] = _mk((b, s), jnp.int32, concrete, "tokens", cfg.vocab)
    return bt


def prefill_batch(cfg: ModelConfig, shape: ShapeConfig,
                  concrete: bool = False) -> Dict[str, Any]:
    bt = train_batch(cfg, shape, concrete)
    bt.pop("labels")
    return bt


def decode_batch(cfg: ModelConfig, shape: ShapeConfig,
                 concrete: bool = False) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(one-token batch, full-length KV cache) for decode cells."""
    b, s = shape.global_batch, shape.seq_len
    bt: Dict[str, Any] = {
        "token": _mk((b, 1), jnp.int32, concrete, "tokens", cfg.vocab),
        "pos": (jnp.asarray(s - 1, jnp.int32) if concrete
                else jax.ShapeDtypeStruct((), jnp.int32)),
    }
    if cfg.embeds_input:
        bt["embed1"] = _mk((b, 1, cfg.d_model), jnp.bfloat16, concrete, "normal")
    model = build_model(cfg)
    if concrete:
        cache = model.init_cache(b, s)
    else:
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return bt, cache
