"""Sharded, layout-independent checkpointing with atomic commits.

Design for 1000+ nodes (DESIGN.md §6):

* every array is saved with its **global** shape; each host writes only the
  shards it owns (`addressable_shards`), as ``<step>.tmp/<host>.npz`` plus a
  JSON manifest, then the coordinator renames ``<step>.tmp -> <step>`` — a
  torn write can never be mistaken for a complete checkpoint;
* restore re-shards to whatever mesh the restarted job has: arrays are
  assembled from saved shard index maps and re-placed with
  ``jax.device_put`` under the *current* sharding — elastic restarts with a
  different device count are exercised in tests;
* ``keep_last`` garbage collection and a ``latest`` pointer for resume.

On this single-host container the host dimension degenerates to one file,
but the format is the multi-host one.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = leaf
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save_checkpoint(ckpt_dir: str | Path, step: int, tree,
                    keep_last: int = 3, host_id: int = 0) -> Path:
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    tmp.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "arrays": {}}
    blobs: Dict[str, np.ndarray] = {}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        manifest["arrays"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
        blobs[key.replace("/", "_")] = arr
        manifest["arrays"][key]["blob"] = key.replace("/", "_")
    np.savez(tmp / f"host{host_id}.npz", **{
        k: v.astype(v.dtype) if v.dtype != np.dtype("bfloat16") else v.view(np.uint16)
        for k, v in blobs.items()})
    # bf16 is not a numpy-native dtype: stored as u16 views, flagged here
    for key, leaf in flat.items():
        manifest["arrays"][key]["bf16"] = str(np.asarray(leaf).dtype) == "bfloat16"
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    (ckpt_dir / "latest").write_text(str(step))

    # GC
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*")
                   if not p.name.endswith(".tmp"))
    for s in steps[:-keep_last]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    p = Path(ckpt_dir) / "latest"
    if not p.exists():
        return None
    step = int(p.read_text().strip())
    if not (Path(ckpt_dir) / f"step_{step:08d}").exists():
        # fall back to scanning (the pointer may be ahead of a GC'd dir)
        steps = sorted(int(q.name.split("_")[1])
                       for q in Path(ckpt_dir).glob("step_*")
                       if not q.name.endswith(".tmp"))
        return steps[-1] if steps else None
    return step


def restore_checkpoint(ckpt_dir: str | Path, step: int, like,
                       shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); re-shards to ``shardings`` if given."""
    import jax.numpy as jnp
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = {}
    for f in d.glob("host*.npz"):
        with np.load(f) as z:
            for k in z.files:
                data[k] = z[k]

    flat_like = _flatten(like)
    out_flat = {}
    for key, like_leaf in flat_like.items():
        info = manifest["arrays"].get(key)
        if info is None:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[info["blob"]]
        if info.get("bf16"):
            arr = arr.view(jnp.bfloat16)
        arr = arr.reshape(info["shape"])
        out_flat[key] = jnp.asarray(arr)

    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    leaves = [out_flat[jax.tree_util.keystr(p)] for p, _ in leaves_paths]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
