"""Deterministic sharded token pipeline.

Straggler-resistant by construction (DESIGN.md §6): batch ``t`` for host
``h`` is a pure function of ``(seed, t, h)`` — no coordinator on the data
path, so a restarted or re-scheduled host resumes at exactly the right
cursor from the checkpointed step alone.  A background prefetch thread
overlaps host-side generation with device compute.

The synthetic corpus is a mixture of Zipf-distributed unigrams and planted
Markov bigram structure, so cross-entropy actually *decreases* during the
end-to-end example runs (quickstart / train examples assert this).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    hosts: int = 1
    host_id: int = 0
    bigram_weight: float = 0.7    # strength of the learnable structure


class SyntheticCorpus:
    """Zipf unigrams + deterministic bigram transitions."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # each token deterministically prefers a successor band
        self.succ = rng.permutation(v).astype(np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % cfg.hosts == 0
        per_host = cfg.global_batch // cfg.hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + cfg.host_id)
        b, s, v = per_host, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.choice(v, size=b, p=self.unigram)
        noise = rng.random((b, s))
        fresh = rng.choice(v, size=(b, s), p=self.unigram)
        for t in range(s):
            follow = self.succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < self.cfg.bigram_weight,
                                      follow, fresh[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch of host batches."""

    def __init__(self, corpus: SyntheticCorpus, start_step: int = 0,
                 depth: int = 2):
        self.corpus = corpus
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.corpus.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
