"""Count providers: one duck type that lets :class:`~repro.core.search
.StructureSearch` run its candidate-family floods through any layer of the
counting stack without knowing which one it got.

The provider protocol is deliberately tiny::

    provider.schema                      # the relational schema counted over
    provider.prepare(lattice)            # build CT tables / warm caches
    provider.version()                   # hashable token; changes on writes
    provider.family_ct(point, keep)      # one complete family CT
    provider.family_ct_many(point, ks)   # batched complete family CTs

Three adapters implement it:

* :class:`LocalCounts` — wraps a bare :class:`~repro.core.strategies
  .Strategy` (the in-process oracle path).
* :class:`ServiceCounts` — wraps a :class:`~repro.serve.service
  .CountingService`, so floods go through the batching/coalescing queue
  and share its warm CT cache with every other client.
* :class:`RouterCounts` — wraps a :class:`~repro.serve.router
  .CountingRouter`, fanning each flood across database shards with
  device-side merging.

Because contingency-table counts are exact integers in every backend, a
family's N_ijk tensor is *bit-identical* regardless of which adapter
produced it — that is what lets the discovery parity tests demand
edge-identical models rather than score-approximate ones.

``version()`` is the mutability hook: it returns ``("db", v)`` for
single-database backends and ``("shards", v0, v1, ...)`` for a router, so
a score memo keyed by ``(version, family)`` composes with the delta
pipeline — any committed :class:`~repro.core.mutate.FactDelta` moves the
token and stale scores stop being addressable.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

from ..core.cache import DEFAULT_TENANT
from ..core.database import RelationalDB
from ..core.strategies import Strategy
from ..core.variables import LatticePoint


def _tenant_token(backend, base: Tuple) -> Tuple:
    """Prefix a version token with the backend's tenant id, so a shared
    score memo keyed by ``(version, family)`` keeps tenants' scores
    disjoint: one tenant's writes move ONLY its own token.  Default-tenant
    backends keep the bare token (single-DB memos are unchanged)."""
    tenant = getattr(backend, "tenant", DEFAULT_TENANT)
    return base if tenant == DEFAULT_TENANT else ("tenant", tenant) + base

__all__ = [
    "LocalCounts",
    "ServiceCounts",
    "RouterCounts",
    "as_count_provider",
]


class LocalCounts:
    """Count provider over a bare in-process :class:`Strategy`.

    This is the oracle path: no queue, no shards — exactly what the
    original local ``StructureSearch`` did.

    Args:
        strategy: any of the four counting strategies.
        db: database to ``prepare`` against; may be omitted when the
            strategy was already prepared elsewhere.
    """

    def __init__(self, strategy: Strategy, db: Optional[RelationalDB] = None):
        self.strategy = strategy
        self._db = db if db is not None else getattr(strategy, "db", None)
        if self._db is None:
            raise ValueError("LocalCounts needs a db or a prepared strategy")
        self.tracer = None

    @property
    def schema(self):
        return self._db.schema

    def prepare(self, lattice: Sequence[LatticePoint]) -> None:
        self.strategy.prepare(self._db, lattice)
        self._db = self.strategy.db

    def version(self) -> Tuple:
        return ("db", self._db.version)

    def family_ct(self, point: LatticePoint, keep):
        return self.strategy.family_ct(point, keep)

    def family_ct_many(self, point: LatticePoint, keeps) -> List:
        return self.strategy.family_ct_many(point, keeps)


class ServiceCounts:
    """Count provider over a running :class:`CountingService`.

    Floods issued by the search loop go through ``complete_many`` — the
    batching queue groups same-signature families, coalesces duplicates
    across concurrent searches, and answers repeats from the service's
    warm CT cache (the ``("fam", atoms, keep)`` namespace is shared with
    the bare strategies, so a cache warmed by one client warms them all).
    """

    def __init__(self, service):
        self.service = service
        self.tracer = getattr(service, "tracer", None)

    @property
    def schema(self):
        return self.service.engine.db.schema

    def prepare(self, lattice: Sequence[LatticePoint]) -> None:
        # The service's engine was planned at construction time; nothing
        # per-lattice to build — completions are computed on demand.
        pass

    def version(self) -> Tuple:
        return _tenant_token(self.service,
                             ("db", self.service.engine.db.version))

    def family_ct(self, point: LatticePoint, keep):
        return self.service.count_complete(point, keep)

    def family_ct_many(self, point: LatticePoint, keeps) -> List:
        return self.service.complete_many([(point, tuple(k)) for k in keeps])


class RouterCounts:
    """Count provider over a :class:`CountingRouter` front-end.

    Each family flood fans out across the database shards; per-shard
    positives merge device-side and the Möbius completion runs once at
    the front-end, so the search loop sees exactly the same integer
    tables a single-database run would.
    """

    def __init__(self, router):
        self.router = router
        self.tracer = getattr(router, "tracer", None)

    @property
    def schema(self):
        return self.router.sdb.schema

    def prepare(self, lattice: Sequence[LatticePoint]) -> None:
        pass

    def version(self) -> Tuple:
        sdb = self.router._snapshot()[0]
        return _tenant_token(
            self.router,
            ("shards",) + tuple(sh.version for sh in sdb.shards))

    def family_ct(self, point: LatticePoint, keep):
        return self.router.count_complete(point, keep)

    def family_ct_many(self, point: LatticePoint, keeps) -> List:
        return self.router.complete_many([(point, tuple(k)) for k in keeps])


def as_count_provider(backend, db: Optional[RelationalDB] = None):
    """Adapt ``backend`` into a count provider.

    Accepts a bare :class:`Strategy` (plus ``db``), a
    :class:`CountingService`, a :class:`CountingRouter`, or any object
    already satisfying the provider protocol (returned unchanged).
    """
    # Lazy imports keep core importable without the serve layer and avoid
    # an import cycle (serve imports discover for its entry points).
    from ..serve.service import CountingService
    from ..serve.router import CountingRouter

    if isinstance(backend, CountingService):
        return ServiceCounts(backend)
    if isinstance(backend, CountingRouter):
        return RouterCounts(backend)
    if isinstance(backend, Strategy):
        return LocalCounts(backend, db)
    needed = ("schema", "prepare", "version", "family_ct", "family_ct_many")
    if all(hasattr(backend, a) for a in needed):
        return backend
    raise TypeError(f"cannot adapt {type(backend).__name__} into a "
                    f"count provider")
