"""Model discovery over the counting stack.

The structure-learning loop (:mod:`repro.core.search`) consumes family
contingency tables; this package makes *where those tables come from*
pluggable — in-process strategy, batching service, or sharded router —
and adds the service-level behaviours that turn one-shot search into a
long-running discovery service: a version-scoped shared score memo,
restart-until-stable consistency against concurrent writes, and
selective delta refresh.  See ``docs/discovery.md``.
"""

from .providers import (LocalCounts, RouterCounts, ServiceCounts,
                        as_count_provider)
from .service import (DiscoveryMetrics, DiscoveryResult, DiscoveryService,
                      RefreshReport, models_signature)

__all__ = [
    "LocalCounts", "RouterCounts", "ServiceCounts", "as_count_provider",
    "DiscoveryMetrics", "DiscoveryResult", "DiscoveryService",
    "RefreshReport", "models_signature",
]
