"""Model discovery as a service: hill-climbing through the counting stack.

:class:`DiscoveryService` runs the learn-and-join structure search of
:mod:`repro.core.search` with its candidate-family floods routed through a
pluggable count provider (:mod:`repro.discover.providers`) — a bare
:class:`~repro.core.strategies.Strategy`, a batching
:class:`~repro.serve.service.CountingService`, or a sharded
:class:`~repro.serve.router.CountingRouter` — so ONE search code path
covers local, served, and distributed execution, and the parity tests can
demand the served/distributed model be *edge-identical* to the local
oracle (counts are exact integers everywhere; the search sorts candidate
moves canonically before the argmax, so ties break the same way on every
backend).

Two service-level behaviours sit on top of the search loop:

* **Shared version-scoped score memo.**  Scores live in one dict keyed by
  ``(version_token, family)``; each search sees a :class:`_MemoView`
  pinned to the token it observed at start.  Concurrent searches over the
  same warm CT cache therefore share every family score, while a
  committed :class:`~repro.core.database.FactDelta` bumps the token and
  silently retires stale entries — a search that raced a write simply
  re-scores under the new token.  ``discover()`` re-runs (warm) until the
  token is stable across a whole search, so results are never computed
  from a torn mix of pre- and post-write counts.

* **Online model refresh.**  ``refresh(changed)`` re-scores only families
  whose recorded dependency sets (the lattice point's relations at
  scoring time) intersect the changed relations: every other family's
  score is carried forward to the new version token (counted in
  ``families_retained``), so only the delta-touched slice of the family
  space is re-counted.  By default the climb then re-runs over the warm
  memo, making the result bit-identical to a from-scratch relearn;
  ``warm_start=True`` instead hill-climbs locally from the current model
  (fewer rounds, possibly a different local optimum).  The
  ``families_rescored`` counter is the test hook proving selectivity.
"""

from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, Optional, Tuple, Union

from ..core.database import AttrDelta, FactDelta, RelationalDB
from ..core.search import BNModel, Family, StructureSearch
from ..core.variables import LatticePoint, build_lattice
from ..obs.hist import CountHistogram, LatencyHistogram
from ..obs.trace import NULL_TRACER
from ..serve.metrics import _LockedMetrics
from .providers import as_count_provider

__all__ = [
    "DiscoveryMetrics",
    "DiscoveryResult",
    "DiscoveryService",
    "RefreshReport",
    "models_signature",
]


def models_signature(models: Dict[LatticePoint, BNModel]) -> dict:
    """Canonical, order-insensitive rendering of a learned model set —
    the shape two discovery runs are compared by in the parity tests."""
    sig = {}
    for point, m in models.items():
        sig[str(point)] = sorted(
            (str(child), tuple(sorted(str(p) for p in ps)))
            for child, ps in m.parents.items())
    return sig


@dataclass
class DiscoveryMetrics(_LockedMetrics):
    """Counters/histograms for one :class:`DiscoveryService`."""
    discoveries: int = 0          # discover() calls completed
    refreshes: int = 0            # refresh() calls completed
    restarts: int = 0             # searches re-run after a version race
    rounds: int = 0               # hill-climbing rounds executed
    families_scored: int = 0      # family CTs scored (memo misses)
    families_rescored: int = 0    # families re-scored by refresh()
    families_retained: int = 0    # scores carried across a version bump
    round_hist: LatencyHistogram = field(
        default_factory=LatencyHistogram)  # per-round wall latency
    rescored_hist: CountHistogram = field(
        default_factory=CountHistogram)    # families re-scored per refresh
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    @classmethod
    def _hist_fields(cls):
        # The base helper matches only LatencyHistogram; this class also
        # carries a CountHistogram, so widen the match.
        return [f.name for f in dataclasses.fields(cls)
                if "Histogram" in str(f.type) and not f.name.startswith("_")]

    def observe_round(self, dt: float) -> None:
        with self._lock:
            self.round_hist.observe(dt)

    def observe_rescored(self, n: int) -> None:
        with self._lock:
            self.rescored_hist.observe(n)

    def snapshot(self) -> dict:
        """JSON-able dict of every counter + histogram summary."""
        return self._base_snapshot()


@dataclass(frozen=True)
class DiscoveryResult:
    """One completed discovery: the per-lattice-point models plus the
    provenance needed to reason about it (which store version it reflects,
    how much scoring work it cost, how often it raced a write)."""
    models: Dict[LatticePoint, BNModel]
    score: float                  # sum of per-point model scores
    version: Tuple                # provider version token the run settled on
    families_scored: int          # memo misses across the run (all restarts)
    restarts: int                 # re-runs forced by version races

    def signature(self) -> dict:
        return models_signature(self.models)


@dataclass(frozen=True)
class RefreshReport:
    """What one ``refresh()`` did: which relations changed, how many
    family scores were re-computed vs carried forward."""
    changed: FrozenSet[str]
    rescored: int                 # families re-scored (dependency hit)
    retained: int                 # scores carried to the new version token
    total_families: int           # families known to the service's memo
    result: DiscoveryResult


class _MemoView:
    """A version-pinned view of the service's shared score memo.

    :class:`StructureSearch` only ever uses ``in`` / ``[]`` get / ``[]``
    set on its score cache, so this implements exactly those three.
    Reads ride on the GIL-atomicity of dict lookups; writes take the
    service lock so they never interleave with the refresh-time rebuild.
    """

    __slots__ = ("_svc", "_token")

    def __init__(self, svc: "DiscoveryService", token: Tuple):
        self._svc = svc
        self._token = token

    def __contains__(self, key: Family) -> bool:
        return (self._token, key) in self._svc._memo

    def __getitem__(self, key: Family) -> float:
        return self._svc._memo[(self._token, key)]

    def __setitem__(self, key: Family, value: float) -> None:
        with self._svc._lock:
            self._svc._memo[(self._token, key)] = value


ChangedSpec = Union[str, FactDelta, AttrDelta,
                    Iterable[Union[str, FactDelta, AttrDelta]]]


class DiscoveryService:
    """Hill-climbing model discovery over any counting backend.

    Args:
        backend: a :class:`Strategy` (with ``db``), a
            :class:`CountingService`, a :class:`CountingRouter`, or a
            ready-made count provider.
        db: database for a bare-strategy backend (ignored otherwise).
        max_chain_length: lattice depth (relationship-chain length).
        max_parents/ess/max_moves/batch_scoring: forwarded to
            :class:`StructureSearch` unchanged.
        max_restarts: cap on version-race re-runs per ``discover()``.
        metrics: share an existing :class:`DiscoveryMetrics`.
        tracer: span sink; defaults to the backend's tracer when it has
            one (so search-round spans land in the same ring as the
            counting spans they caused).
        memo: share an existing score-memo dict across several discovery
            services — the multi-tenant registry passes ONE dict to every
            tenant's service.  Safe because memo keys are
            ``(version_token, family)`` and tenant backends prefix their
            tokens with the tenant id, so entries stay disjoint: one
            tenant's writes move only its own token, and a shared-memo
            refresh retains other tokens' entries instead of garbage-
            collecting them.

    Usage::

        svc = DiscoveryService(router)          # or service / strategy
        result = svc.discover()
        report = svc.refresh(delta)             # selective re-score
    """

    def __init__(self, backend, *, db: Optional[RelationalDB] = None,
                 max_chain_length: int = 2, max_parents: int = 3,
                 ess: float = 1.0, max_moves: int = 200,
                 batch_scoring: bool = True, max_restarts: int = 64,
                 metrics: Optional[DiscoveryMetrics] = None,
                 tracer=None,
                 memo: Optional[Dict[Tuple[Tuple, Family], float]] = None):
        self.provider = as_count_provider(backend, db)
        self.schema = self.provider.schema
        self.lattice = build_lattice(self.schema, max_chain_length)
        self.provider.prepare(self.lattice)
        self.max_parents = max_parents
        self.ess = ess
        self.max_moves = max_moves
        self.batch_scoring = batch_scoring
        self.max_restarts = max_restarts
        self.metrics = metrics if metrics is not None else DiscoveryMetrics()
        self.tracer = (tracer if tracer is not None
                       else getattr(self.provider, "tracer", None)
                       or NULL_TRACER)
        self._lock = threading.Lock()
        self._shared_memo = memo is not None
        self._memo: Dict[Tuple[Tuple, Family], float] = (
            memo if memo is not None else {})
        self._deps: Dict[Family, FrozenSet[str]] = {}
        self._models: Optional[Dict[LatticePoint, BNModel]] = None
        self._token: Optional[Tuple] = None

    # -- internals ------------------------------------------------------------
    def _round_cb(self, point: LatticePoint, n_moves: int, n_scored: int,
                  t0: float, t1: float) -> None:
        self.metrics.inc(rounds=1, families_scored=n_scored)
        self.metrics.observe_round(t1 - t0)
        if self.tracer.enabled:
            self.tracer.record("discover.round", t0, t1, point=str(point),
                               moves=n_moves, scored=n_scored)

    def _make_search(self, token: Tuple) -> StructureSearch:
        return StructureSearch(
            None, None, counts=self.provider, schema=self.schema,
            max_parents=self.max_parents, ess=self.ess,
            max_moves=self.max_moves, batch_scoring=self.batch_scoring,
            score_cache=_MemoView(self, token), round_cb=self._round_cb)

    def _run_stable(self, init_models: Optional[Dict[LatticePoint, BNModel]]
                    ) -> Tuple[Dict[LatticePoint, BNModel], Tuple, int, int]:
        """Run searches until one completes without the provider version
        moving underneath it.  Re-runs are warm: any family whose score
        landed under the final token (or was carried forward) is a memo
        hit.  Returns (models, token, families_scored, restarts)."""
        scored = 0
        for attempt in range(self.max_restarts + 1):
            token = self.provider.version()
            search = self._make_search(token)
            models = search.run(self.lattice, init_models=init_models)
            scored += search.families_scored
            with self._lock:
                self._deps.update(search.family_deps)
            if self.provider.version() == token:
                return models, token, scored, attempt
            self.metrics.inc(restarts=1)
        raise RuntimeError(f"discovery did not stabilise within "
                           f"{self.max_restarts} restarts (writes never "
                           f"quiesced)")

    # -- public API -----------------------------------------------------------
    def discover(self) -> DiscoveryResult:
        """Learn models for every lattice point from the current store
        state.  Safe to call concurrently from many threads: all calls
        share the memo (warm-cache hits) and each returns a result
        consistent with a single store version."""
        with self.tracer.span("discover.run"):
            models, token, scored, restarts = self._run_stable(None)
        with self._lock:
            self._models = models
            self._token = token
        self.metrics.inc(discoveries=1)
        return DiscoveryResult(models=models,
                               score=sum(m.score for m in models.values()),
                               version=token, families_scored=scored,
                               restarts=restarts)

    def refresh(self, changed: ChangedSpec, *,
                warm_start: bool = False) -> RefreshReport:
        """Selectively re-learn after committed writes.

        ``changed`` names the mutated relation(s) — a relation name, a
        :class:`FactDelta`, an :class:`~repro.core.database.AttrDelta`,
        or an iterable of any mix.  Scores of families whose dependency
        sets are disjoint from ``changed`` are carried forward to the new
        version token; every other family is re-scored lazily as the
        hill-climb touches it — that selective re-counting is where the
        savings live, since counting (not move enumeration) is the search
        bottleneck.  An :class:`AttrDelta` anywhere in ``changed``
        disables carry-forward entirely (conservative full rescore):
        family dependency sets record relation names, and almost every
        family's sufficient statistics depend on entity attributes, so
        no selective match is sound for attribute writes.

        With ``warm_start=False`` (default) the climb restarts from the
        empty graph over the warm memo, which makes the refreshed model
        **bit-identical to a from-scratch relearn** on the mutated store:
        same init, same canonical move order, same scores (retained
        entries equal what a fresh count would produce, because their
        dependencies did not change).  ``warm_start=True`` instead
        hill-climbs locally from the current model — fewer rounds, same
        selective re-scoring, but greedy single-edge moves cannot reverse
        an edge in one step, so the result may be a different (equally
        local) optimum than a full relearn.
        """
        rels, attr_write = self._split_changed(changed)
        with self.tracer.span("discover.refresh", changed=sorted(rels),
                              attr_write=attr_write):
            if self._models is None:      # nothing to refresh from
                result = self.discover()
                report = RefreshReport(changed=rels,
                                       rescored=result.families_scored,
                                       retained=0,
                                       total_families=len(self._deps),
                                       result=result)
                self.metrics.inc(refreshes=1,
                                 families_rescored=report.rescored)
                self.metrics.observe_rescored(report.rescored)
                return report

            new_token = self.provider.version()
            retained = self._carry_forward(new_token,
                                           None if attr_write else rels)
            init = self._models if warm_start else None
            models, token, scored, restarts = self._run_stable(init)
        with self._lock:
            self._models = models
            self._token = token
            total = len(self._deps)
        self.metrics.inc(refreshes=1, families_rescored=scored,
                         families_retained=retained)
        self.metrics.observe_rescored(scored)
        result = DiscoveryResult(models=models,
                                 score=sum(m.score for m in models.values()),
                                 version=token, families_scored=scored,
                                 restarts=restarts)
        return RefreshReport(changed=rels, rescored=scored,
                             retained=retained, total_families=total,
                             result=result)

    def reset_memo(self) -> None:
        """Drop every memoized family score (but no CT cache state) —
        benchmarks use this to re-measure scoring work over warm counts.
        On a shared memo this clears IN PLACE (every sharer's scores go,
        including other tenants')."""
        with self._lock:
            if self._shared_memo:
                self._memo.clear()
            else:
                self._memo = {}

    def stats(self) -> dict:
        return self.metrics.snapshot()

    # -- refresh plumbing -----------------------------------------------------
    @staticmethod
    def _split_changed(changed: ChangedSpec
                       ) -> Tuple[FrozenSet[str], bool]:
        """Normalise a changed-spec into ``(relation names, any
        attribute write?)``.  Attribute writes are reported as
        ``attr:etype.name`` strings in the relation set (for the refresh
        report) but carry-forward treats them as change-everything."""
        if isinstance(changed, str):
            return frozenset((changed,)), False
        if isinstance(changed, FactDelta):
            return frozenset((changed.rel,)), False
        if isinstance(changed, AttrDelta):
            return frozenset(f"attr:{changed.etype}.{a}"
                             for a in changed.attrs), True
        rels, has_attr = set(), False
        for item in changed:
            if isinstance(item, AttrDelta):
                has_attr = True
                rels.update(f"attr:{item.etype}.{a}" for a in item.attrs)
            elif isinstance(item, FactDelta):
                rels.add(item.rel)
            else:
                rels.add(str(item))
        return frozenset(rels), has_attr

    def _carry_forward(self, new_token: Tuple,
                       changed: Optional[FrozenSet[str]]) -> int:
        """Move scores whose dependencies are disjoint from ``changed``
        from the previous model's token to ``new_token``; drop everything
        else (it will be re-scored lazily).  ``changed=None`` means
        *everything* changed (an attribute write): nothing is carried
        forward, old-token entries are still dropped/rebuilt so the memo
        does not leak.  A private memo is rebuilt
        into a fresh dict and swapped atomically so concurrent readers
        only ever see a complete mapping; a SHARED memo is edited in
        place instead — other sharers' tokens (other tenants') are
        retained rather than garbage-collected, so one tenant's write
        never invalidates another's scores, and a reader racing the move
        at worst misses a score transiently (costing one re-score)."""
        retained = 0
        with self._lock:
            old_token = self._token
            if self._shared_memo:
                if old_token == new_token:
                    return 0
                moves, drops = [], []
                for (tok, fam), s in list(self._memo.items()):
                    if tok != old_token:
                        continue
                    deps = self._deps.get(fam)
                    if (changed is not None and deps is not None
                            and not (deps & changed)):
                        moves.append(((new_token, fam), s))
                    drops.append((tok, fam))
                for k in drops:
                    self._memo.pop(k, None)
                for k, s in moves:
                    self._memo[k] = s
                return len(moves)
            memo: Dict[Tuple[Tuple, Family], float] = {}
            for (tok, fam), s in self._memo.items():
                if tok == new_token:
                    memo[(tok, fam)] = s
                elif tok == old_token:
                    deps = self._deps.get(fam)
                    if (changed is not None and deps is not None
                            and not (deps & changed)):
                        memo[(new_token, fam)] = s
                        retained += 1
            self._memo = memo
        return retained
